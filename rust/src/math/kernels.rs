//! Pluggable ring-arithmetic kernel layer: every hot inner loop of the
//! NTT/FFT/keyswitch core behind one trait, with a scalar reference
//! implementation and a vectorized implementation selected at table
//! construction (`GLYPH_KERNELS=scalar|simd`, default `simd`).
//!
//! # The two implementations
//!
//! * [`ScalarKernels`] — the pre-existing loops, verbatim: branchy
//!   `add_mod`/`sub_mod` butterflies with fully-reduced values in `[0, p)`
//!   at every step. This is the reference semantics.
//! * [`SimdKernels`] — Harvey lazy-reduction butterflies: values stay
//!   redundant in `[0, 4p)` (forward) / `[0, 2p)` (inverse) through the
//!   whole layer loop, Shoup multiplies never correct, and one branchless
//!   min-sweep canonicalizes at the end. Every loop body is straight-line
//!   (no data-dependent branches), so LLVM auto-vectorizes it onto
//!   AVX2/AVX-512 (or NEON) lanes under `-C target-cpu=native` — the
//!   portable route to SIMD on the stable toolchain CI pins
//!   (nightly `std::simd` and unsafe `std::arch` intrinsics are both
//!   avoided on purpose; the CI kernel matrix builds with
//!   `RUSTFLAGS=-C target-cpu=native` to unlock the wide lanes).
//!
//! Both implementations compute *exact* mod-p integer arithmetic (and
//! bit-identical f64 expressions on the FFT side — note: no FMA, which
//! would change roundings), so every consumer is bit-identical under either
//! kernel set. `tests/kernel_equivalence.rs` enforces this directly and the
//! five conformance suites (`pbs_equivalence`, `bgv_mac_equivalence`,
//! `switch_roundtrip`, `train_step_golden`, `backend_equivalence`) enforce
//! it end-to-end under the CI matrix.

use super::fft::Cplx;
use super::modarith::{add_mod, barrett_mul, mul_shoup, mul_shoup_lazy, sub_mod};
use std::sync::OnceLock;

/// The hot inner loops of the ring-arithmetic core. One `&'static`
/// implementation is attached to each `NttTable`/`TorusFft`/key-switch key
/// at construction; everything downstream dispatches through it.
///
/// Contracts (shared by all implementations):
/// * NTT values are canonical `[0, p)` at entry and exit of every method —
///   lazy redundancy is an implementation detail that never escapes.
/// * `p < 2^32` (RNS limb primes), so `4p < 2^34` leaves ample headroom
///   in `u64` lanes.
/// * FFT methods must evaluate the same f64 expression tree as the scalar
///   reference (same order, no FMA contraction) to stay bit-identical.
#[allow(clippy::too_many_arguments)]
pub trait RingKernels: Send + Sync {
    /// Implementation name (`"scalar"` / `"simd"`), for logs and bench JSON.
    fn name(&self) -> &'static str;

    /// In-place forward negacyclic NTT (CT/DIT, ψ-twisted, bit-reversed
    /// output). `psi_rev[m+i]` / its Shoup companion index exactly as built
    /// by `NttTable::new`.
    fn ntt_forward(&self, p: u64, psi_rev: &[u64], psi_rev_shoup: &[u64], a: &mut [u64]);

    /// In-place inverse negacyclic NTT (GS/DIF) including the 1/N scale.
    fn ntt_inverse(
        &self,
        p: u64,
        inv_psi_rev: &[u64],
        inv_psi_rev_shoup: &[u64],
        inv_n: u64,
        inv_n_shoup: u64,
        a: &mut [u64],
    );

    /// `a[i] = a[i]·b[i] mod p` (Barrett).
    fn pointwise(&self, p: u64, barrett: u64, a: &mut [u64], b: &[u64]);

    /// `acc[i] += a[i]·b[i] mod p`.
    fn pointwise_acc(&self, p: u64, barrett: u64, acc: &mut [u64], a: &[u64], b: &[u64]);

    /// Fused `acc[i] += a[i]·b[i] + c[i]·d[i] mod p` (BGV cross term).
    fn pointwise_acc2(
        &self,
        p: u64,
        barrett: u64,
        acc: &mut [u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        d: &[u64],
    );

    /// `a[i] = a[i]·s mod p` with a Shoup-precomputed constant scalar.
    fn scalar_mul(&self, p: u64, s: u64, s_shoup: u64, a: &mut [u64]);

    /// The radix-2 DIT stage loop of the complex FFT, on an already
    /// bit-reverse-permuted buffer. Twiddles arrive as structure-of-arrays
    /// re/im slabs in the per-stage layout built by `TorusFft::new`.
    fn fft_stages(&self, tw_re: &[f64], tw_im: &[f64], a: &mut [Cplx]);

    /// Frequency-domain `acc[i] += a[i]·b[i]` (complex).
    fn fft_mul_acc(&self, a: &[Cplx], b: &[Cplx], acc: &mut [Cplx]);

    /// Balanced gadget decomposition of a whole torus32 polynomial into a
    /// digit-major matrix: `out[j·n + i]` = digit `j` of `a[i]`, each in
    /// `[-B/2, B/2)` with `B = 2^base_bit` (MSB-first, offset trick).
    fn decompose_poly(&self, a: &[u32], levels: usize, base_bit: u32, out: &mut [i32]);

    /// Key-switch AXPY: `out[k] -= d·row[k]` on wrapping torus32 lanes.
    fn ks_submul(&self, out: &mut [u32], row: &[u32], d: u32);
}

/// Offset whose addition turns truncating base-2^bb digit extraction into
/// balanced (centered) digits: `Σ_j (B/2) << (32 - (j+1)·bb)`. Shared by the
/// TRGSW gadget decomposition and the LWE key switch.
#[inline]
pub fn gadget_offset(levels: usize, base_bit: u32) -> u32 {
    let half = 1u32 << (base_bit - 1);
    let mut offset = 0u32;
    for j in 0..levels {
        offset = offset.wrapping_add(half << (32 - (j as u32 + 1) * base_bit));
    }
    offset
}

// ---------------------------------------------------------------------------
// Scalar reference implementation
// ---------------------------------------------------------------------------

/// Fully-reduced reference loops — the semantics both kernel sets must match.
pub struct ScalarKernels;

#[allow(clippy::too_many_arguments)]
impl RingKernels for ScalarKernels {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn ntt_forward(&self, p: u64, psi_rev: &[u64], psi_rev_shoup: &[u64], a: &mut [u64]) {
        let n = a.len();
        let mut t = n;
        let mut m = 1usize;
        while m < n {
            t >>= 1;
            for i in 0..m {
                let w = psi_rev[m + i];
                let ws = psi_rev_shoup[m + i];
                let j1 = 2 * i * t;
                let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
                for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
                    let u = *x;
                    let v = mul_shoup(*y, w, ws, p);
                    *x = add_mod(u, v, p);
                    *y = sub_mod(u, v, p);
                }
            }
            m <<= 1;
        }
    }

    fn ntt_inverse(
        &self,
        p: u64,
        inv_psi_rev: &[u64],
        inv_psi_rev_shoup: &[u64],
        inv_n: u64,
        inv_n_shoup: u64,
        a: &mut [u64],
    ) {
        let n = a.len();
        let mut t = 1usize;
        let mut m = n;
        while m > 1 {
            let h = m >> 1;
            for i in 0..h {
                let w = inv_psi_rev[h + i];
                let ws = inv_psi_rev_shoup[h + i];
                let j1 = 2 * i * t;
                let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
                for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
                    let u = *x;
                    let v = *y;
                    *x = add_mod(u, v, p);
                    *y = mul_shoup(sub_mod(u, v, p), w, ws, p);
                }
            }
            t <<= 1;
            m = h;
        }
        for x in a.iter_mut() {
            *x = mul_shoup(*x, inv_n, inv_n_shoup, p);
        }
    }

    fn pointwise(&self, p: u64, barrett: u64, a: &mut [u64], b: &[u64]) {
        for (x, &y) in a.iter_mut().zip(b.iter()) {
            *x = barrett_mul(*x, y, p, barrett);
        }
    }

    fn pointwise_acc(&self, p: u64, barrett: u64, acc: &mut [u64], a: &[u64], b: &[u64]) {
        for ((s, &x), &y) in acc.iter_mut().zip(a).zip(b) {
            *s = add_mod(*s, barrett_mul(x, y, p, barrett), p);
        }
    }

    fn pointwise_acc2(
        &self,
        p: u64,
        barrett: u64,
        acc: &mut [u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        d: &[u64],
    ) {
        for i in 0..acc.len() {
            let cross =
                add_mod(barrett_mul(a[i], b[i], p, barrett), barrett_mul(c[i], d[i], p, barrett), p);
            acc[i] = add_mod(acc[i], cross, p);
        }
    }

    fn scalar_mul(&self, p: u64, s: u64, s_shoup: u64, a: &mut [u64]) {
        for x in a.iter_mut() {
            *x = mul_shoup(*x, s, s_shoup, p);
        }
    }

    fn fft_stages(&self, tw_re: &[f64], tw_im: &[f64], a: &mut [Cplx]) {
        let m = a.len();
        let mut h = 1usize;
        let mut tw_off = 0usize;
        while h < m {
            for start in (0..m).step_by(2 * h) {
                for k in 0..h {
                    let w = Cplx::new(tw_re[tw_off + k], tw_im[tw_off + k]);
                    let u = a[start + k];
                    let v = a[start + k + h].mul(w);
                    a[start + k] = u.add(v);
                    a[start + k + h] = u.sub(v);
                }
            }
            tw_off += h;
            h <<= 1;
        }
    }

    fn fft_mul_acc(&self, a: &[Cplx], b: &[Cplx], acc: &mut [Cplx]) {
        for ((&x, &y), s) in a.iter().zip(b).zip(acc.iter_mut()) {
            x.mul_add_acc(y, s);
        }
    }

    fn decompose_poly(&self, a: &[u32], levels: usize, base_bit: u32, out: &mut [i32]) {
        let n = a.len();
        debug_assert_eq!(out.len(), levels * n);
        let half = 1i32 << (base_bit - 1);
        let mask = (1u32 << base_bit) - 1;
        let offset = gadget_offset(levels, base_bit);
        for (i, &x) in a.iter().enumerate() {
            let xx = x.wrapping_add(offset);
            for j in 0..levels {
                let shift = 32 - (j as u32 + 1) * base_bit;
                out[j * n + i] = (((xx >> shift) & mask) as i32) - half;
            }
        }
    }

    fn ks_submul(&self, out: &mut [u32], row: &[u32], d: u32) {
        for (x, &y) in out.iter_mut().zip(row) {
            *x = x.wrapping_sub(d.wrapping_mul(y));
        }
    }
}

// ---------------------------------------------------------------------------
// Vectorized implementation: Harvey lazy reduction, branchless sweeps
// ---------------------------------------------------------------------------

/// Branchless `min(r, r−p)` canonicalization: for `r < 2p` the subtraction
/// wraps past 2^63 exactly when `r < p`, so `min` picks the reduced value.
#[inline(always)]
fn reduce_once(r: u64, p: u64) -> u64 {
    r.min(r.wrapping_sub(p))
}

/// Lazy-reduction loops shaped for LLVM auto-vectorization (see module docs).
pub struct SimdKernels;

#[allow(clippy::too_many_arguments)]
impl RingKernels for SimdKernels {
    fn name(&self) -> &'static str {
        "simd"
    }

    /// Harvey forward butterflies: inputs to each layer are `< 4p`; the top
    /// lane is folded to `[0, 2p)` by one min, the Shoup product lands in
    /// `[0, 2p)` for *any* 64-bit operand, so `x' = x0 + t < 4p` and
    /// `y' = x0 − t + 2p ∈ (0, 4p)` restore the invariant with zero
    /// data-dependent branches. One final two-step min-sweep returns `[0, p)`.
    fn ntt_forward(&self, p: u64, psi_rev: &[u64], psi_rev_shoup: &[u64], a: &mut [u64]) {
        let n = a.len();
        let two_p = 2 * p;
        let mut t = n;
        let mut m = 1usize;
        while m < n {
            t >>= 1;
            for i in 0..m {
                let w = psi_rev[m + i];
                let ws = psi_rev_shoup[m + i];
                let j1 = 2 * i * t;
                let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
                for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
                    let u = reduce_once(*x, two_p);
                    let v = mul_shoup_lazy(*y, w, ws, p);
                    *x = u + v;
                    *y = u.wrapping_sub(v).wrapping_add(two_p);
                }
            }
            m <<= 1;
        }
        for x in a.iter_mut() {
            *x = reduce_once(reduce_once(*x, two_p), p);
        }
    }

    /// Lazy GS inverse: the `[0, 2p)` invariant holds into every layer
    /// (canonical entry values trivially satisfy it); sums are folded back
    /// once, differences are absorbed by the Shoup multiply (valid for any
    /// 64-bit operand). The 1/N sweep canonicalizes.
    fn ntt_inverse(
        &self,
        p: u64,
        inv_psi_rev: &[u64],
        inv_psi_rev_shoup: &[u64],
        inv_n: u64,
        inv_n_shoup: u64,
        a: &mut [u64],
    ) {
        let n = a.len();
        let two_p = 2 * p;
        let mut t = 1usize;
        let mut m = n;
        while m > 1 {
            let h = m >> 1;
            for i in 0..h {
                let w = inv_psi_rev[h + i];
                let ws = inv_psi_rev_shoup[h + i];
                let j1 = 2 * i * t;
                let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
                for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
                    let u = *x;
                    let v = *y;
                    *x = reduce_once(u + v, two_p);
                    let d = u.wrapping_sub(v).wrapping_add(two_p);
                    *y = mul_shoup_lazy(d, w, ws, p);
                }
            }
            t <<= 1;
            m = h;
        }
        for x in a.iter_mut() {
            *x = reduce_once(mul_shoup_lazy(*x, inv_n, inv_n_shoup, p), p);
        }
    }

    fn pointwise(&self, p: u64, barrett: u64, a: &mut [u64], b: &[u64]) {
        for (x, &y) in a.iter_mut().zip(b.iter()) {
            *x = barrett_mul(*x, y, p, barrett);
        }
    }

    fn pointwise_acc(&self, p: u64, barrett: u64, acc: &mut [u64], a: &[u64], b: &[u64]) {
        for ((s, &x), &y) in acc.iter_mut().zip(a).zip(b) {
            // branchless add_mod: s + prod < 2p fits u64, one min folds back
            *s = reduce_once(*s + barrett_mul(x, y, p, barrett), p);
        }
    }

    fn pointwise_acc2(
        &self,
        p: u64,
        barrett: u64,
        acc: &mut [u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        d: &[u64],
    ) {
        for i in 0..acc.len() {
            let ab = barrett_mul(a[i], b[i], p, barrett);
            let cd = barrett_mul(c[i], d[i], p, barrett);
            let cross = reduce_once(ab + cd, p);
            acc[i] = reduce_once(acc[i] + cross, p);
        }
    }

    fn scalar_mul(&self, p: u64, s: u64, s_shoup: u64, a: &mut [u64]) {
        for x in a.iter_mut() {
            *x = reduce_once(mul_shoup_lazy(*x, s, s_shoup, p), p);
        }
    }

    /// Same stage schedule as the scalar reference, but the innermost loop
    /// runs over four zipped slices (lo/hi halves, re/im twiddle slabs) so
    /// the compiler sees unit-stride bounds-free lanes. The arithmetic
    /// expression per element is *identical* to the scalar path (and FMA is
    /// never emitted for `a*b + c` written as two ops under the default
    /// `-C fma=off`-equivalent semantics), keeping results bit-identical.
    fn fft_stages(&self, tw_re: &[f64], tw_im: &[f64], a: &mut [Cplx]) {
        let m = a.len();
        let mut h = 1usize;
        let mut tw_off = 0usize;
        while h < m {
            let wr = &tw_re[tw_off..tw_off + h];
            let wi = &tw_im[tw_off..tw_off + h];
            for start in (0..m).step_by(2 * h) {
                let (lo, hi) = a[start..start + 2 * h].split_at_mut(h);
                for (((x, y), &wre), &wim) in lo.iter_mut().zip(hi.iter_mut()).zip(wr).zip(wi) {
                    let u = *x;
                    let yv = *y;
                    let vre = yv.re * wre - yv.im * wim;
                    let vim = yv.re * wim + yv.im * wre;
                    *x = Cplx::new(u.re + vre, u.im + vim);
                    *y = Cplx::new(u.re - vre, u.im - vim);
                }
            }
            tw_off += h;
            h <<= 1;
        }
    }

    fn fft_mul_acc(&self, a: &[Cplx], b: &[Cplx], acc: &mut [Cplx]) {
        for ((&x, &y), s) in a.iter().zip(b).zip(acc.iter_mut()) {
            // spelled out (not via mul_add_acc) so the slice-zip form stays
            // the same expression tree: products, subtract/add, accumulate
            s.re += x.re * y.re - x.im * y.im;
            s.im += x.re * y.im + x.im * y.re;
        }
    }

    /// Level-major passes: shift and mask are loop constants per level, so
    /// each pass is a pure shift/and/sub sweep over u32 lanes.
    fn decompose_poly(&self, a: &[u32], levels: usize, base_bit: u32, out: &mut [i32]) {
        let n = a.len();
        debug_assert_eq!(out.len(), levels * n);
        let half = 1i32 << (base_bit - 1);
        let mask = (1u32 << base_bit) - 1;
        let offset = gadget_offset(levels, base_bit);
        for j in 0..levels {
            let shift = 32 - (j as u32 + 1) * base_bit;
            for (d, &x) in out[j * n..(j + 1) * n].iter_mut().zip(a) {
                *d = (((x.wrapping_add(offset) >> shift) & mask) as i32) - half;
            }
        }
    }

    fn ks_submul(&self, out: &mut [u32], row: &[u32], d: u32) {
        for (x, &y) in out.iter_mut().zip(row) {
            *x = x.wrapping_sub(d.wrapping_mul(y));
        }
    }
}

// ---------------------------------------------------------------------------
// Selection
// ---------------------------------------------------------------------------

static SELECTED: OnceLock<&'static dyn RingKernels> = OnceLock::new();

/// The scalar reference kernels.
pub fn scalar_kernels() -> &'static dyn RingKernels {
    &ScalarKernels
}

/// The vectorized lazy-reduction kernels.
pub fn simd_kernels() -> &'static dyn RingKernels {
    &SimdKernels
}

/// Process-wide default, read once from `GLYPH_KERNELS` (`scalar` | `simd`;
/// unset defaults to `simd`). Every `NttTable::new`/`TorusFft::new`/key-switch
/// key generation picks this up; tests and benches that need both pin them
/// explicitly via the `with_kernels` constructors instead.
pub fn default_kernels() -> &'static dyn RingKernels {
    *SELECTED.get_or_init(|| match std::env::var("GLYPH_KERNELS").as_deref() {
        Ok("scalar") => scalar_kernels(),
        Ok("simd") | Err(_) => simd_kernels(),
        Ok(other) => panic!("GLYPH_KERNELS must be 'scalar' or 'simd', got '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::modarith::{barrett_precompute, shoup_precompute};
    use crate::math::rng::GlyphRng;

    const P: u64 = 469762049; // 7 * 2^26 + 1

    #[test]
    fn decompose_poly_implementations_agree() {
        let mut rng = GlyphRng::new(11);
        let n = 64;
        let a: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
        for (levels, bb) in [(2usize, 8u32), (3, 7), (8, 2), (4, 4)] {
            let mut ds = vec![0i32; levels * n];
            let mut dv = vec![0i32; levels * n];
            ScalarKernels.decompose_poly(&a, levels, bb, &mut ds);
            SimdKernels.decompose_poly(&a, levels, bb, &mut dv);
            assert_eq!(ds, dv, "levels={levels} bb={bb}");
            // reconstruction: sum_j d_j * 2^(32-(j+1)bb) ≈ a (within the
            // truncated tail of the gadget)
            for i in 0..n {
                let mut acc = 0u32;
                for j in 0..levels {
                    let scale = 1u32 << (32 - (j as u32 + 1) * bb);
                    acc = acc.wrapping_add((ds[j * n + i] as u32).wrapping_mul(scale));
                }
                let err = a[i].wrapping_sub(acc);
                let err_centered = (err as i32 as i64).unsigned_abs();
                assert!(
                    err_centered <= 1u64 << (32 - levels as u32 * bb),
                    "i={i} levels={levels} bb={bb} err={err_centered}"
                );
            }
        }
    }

    #[test]
    fn pointwise_kernels_agree_at_extremes() {
        let br = barrett_precompute(P);
        let vals = [0u64, 1, 2, P / 2, P - 2, P - 1];
        for &x in &vals {
            for &y in &vals {
                let mut a1 = [x];
                let mut a2 = [x];
                ScalarKernels.pointwise(P, br, &mut a1, &[y]);
                SimdKernels.pointwise(P, br, &mut a2, &[y]);
                assert_eq!(a1, a2, "x={x} y={y}");
                let mut s1 = [P - 1];
                let mut s2 = [P - 1];
                ScalarKernels.pointwise_acc(P, br, &mut s1, &[x], &[y]);
                SimdKernels.pointwise_acc(P, br, &mut s2, &[x], &[y]);
                assert_eq!(s1, s2, "acc x={x} y={y}");
            }
        }
    }

    #[test]
    fn scalar_mul_kernels_agree() {
        let mut rng = GlyphRng::new(5);
        let a: Vec<u64> = (0..128).map(|_| rng.next_u64() % P).collect();
        for s in [0u64, 1, P / 3, P - 1] {
            let ss = shoup_precompute(s, P);
            let mut b1 = a.clone();
            let mut b2 = a.clone();
            ScalarKernels.scalar_mul(P, s, ss, &mut b1);
            SimdKernels.scalar_mul(P, s, ss, &mut b2);
            assert_eq!(b1, b2, "s={s}");
        }
    }

    #[test]
    fn default_selection_is_stable() {
        // Whatever the environment says, repeated calls agree.
        let first = default_kernels().name();
        let second = default_kernels().name();
        assert_eq!(first, second);
        assert!(first == "scalar" || first == "simd");
    }
}
