//! `u64` modular arithmetic via `u128` intermediates, deterministic
//! Miller–Rabin primality, and NTT-friendly prime generation.
//!
//! All BGV moduli are primes `p ≡ 1 (mod 2^26)` (DESIGN.md §2.2): this makes
//! them automatically NTT-friendly for any ring degree `N ≤ 2^25` *and*
//! guarantees `q = Π p_i ≡ 1 (mod t)` for the power-of-two plaintext modulus
//! `t ≤ 2^26`, which is what gives Glyph its noise-free LSB↔MSB switch.
//!
//! # Which multiply to use where
//!
//! * [`mul_mod`] — the general `u128 %` schoolbook reduction. Works for any
//!   `u64` modulus but compiles to a hardware divide; **cold paths only**
//!   (key generation, CRT reconstruction, Miller–Rabin on arbitrary `u64`).
//! * [`barrett_mul`] / [`barrett_reduce`] — both operands variable, modulus
//!   `< 2^32` with a precomputed [`barrett_precompute`] constant. One
//!   mul-high + one mul + one conditional correction; the pointwise-pass
//!   workhorse (`NttTable::pointwise*`, the relin digit lift).
//! * [`mul_shoup`] — one operand is a *constant* known ahead of time with a
//!   precomputed [`shoup_precompute`] companion (NTT twiddles, RNS scalar
//!   maps, the extractor's rescale constants). Cheapest fully-reduced form.
//! * [`mul_shoup_lazy`] — same, but skips the final correction and returns a
//!   value in `[0, 2p)`. The Harvey lazy-reduction NTT butterflies
//!   (`math/kernels.rs`) live on this; callers must track the redundancy.
//!
//! The seeded property suite `tests/modarith_props.rs` pits all variants
//! against each other across edge moduli (p near 2^32, a = b = p−1).

/// `a * b mod m` without overflow. General but slow (`u128 %` emits a
/// hardware divide) — see the module docs for the hot-path alternatives.
#[inline(always)]
pub fn mul_mod(a: u64, b: u64, m: u64) -> u64 {
    ((a as u128 * b as u128) % m as u128) as u64
}

/// Barrett constant `⌊2^64 / p⌋` for [`barrett_mul`]/[`barrett_reduce`].
/// Requires `2 ≤ p < 2^64` (for `p = 1` the constant does not fit).
#[inline]
pub fn barrett_precompute(p: u64) -> u64 {
    debug_assert!(p >= 2, "Barrett constant undefined for p < 2");
    ((1u128 << 64) / p as u128) as u64
}

/// Barrett reduction of a 64-bit product modulo a `p < 2^32` prime:
/// `q = ⌊t·⌊2^64/p⌋ / 2^64⌋`, remainder corrected once. The estimate error
/// is provably `< 2p` for any `t < 2^64` (with β = 2^64 and ρ = β mod p:
/// `r ≤ t·ρ/β + p < ρ + p < 2p`), so a single branchless min-correction
/// yields the canonical representative. ~3× faster than the `u128 %` the
/// compiler emits (EXPERIMENTS.md §Perf).
#[inline(always)]
pub fn barrett_mul(a: u64, b: u64, p: u64, barrett: u64) -> u64 {
    debug_assert!(a < (1 << 32) && b < (1 << 32), "Barrett operands must fit 32 bits");
    let t = a.wrapping_mul(b); // exact: a,b < 2^32
    barrett_reduce(t, p, barrett)
}

/// Canonical `x mod p` via the Barrett constant, valid for **any** `u64 x`
/// (same error bound as [`barrett_mul`]). Replaces `%` where the modulus is
/// hot-loop constant but the value is not a product of 32-bit operands.
#[inline(always)]
pub fn barrett_reduce(x: u64, p: u64, barrett: u64) -> u64 {
    let q = ((x as u128 * barrett as u128) >> 64) as u64;
    let r = x.wrapping_sub(q.wrapping_mul(p));
    // r < 2p: one min-correction is exact. `r - p` wraps above 2^63 when
    // r < p, so `min` selects the canonical representative branchlessly.
    r.min(r.wrapping_sub(p))
}

/// Shoup companion `⌊w · 2^64 / p⌋` of a constant multiplicand `w < p`.
#[inline]
pub fn shoup_precompute(w: u64, p: u64) -> u64 {
    debug_assert!(w < p, "Shoup multiplicand must be reduced");
    (((w as u128) << 64) / p as u128) as u64
}

/// Shoup modular multiplication: canonical `a · w mod p` with precomputed
/// `w_shoup =` [`shoup_precompute`]`(w, p)`. One u128 mul-high, no division;
/// correct for any `a < 2^64` (the lazy form below is `< 2p`, one
/// min-correction canonicalizes).
#[inline(always)]
pub fn mul_shoup(a: u64, w: u64, w_shoup: u64, p: u64) -> u64 {
    let r = mul_shoup_lazy(a, w, w_shoup, p);
    r.min(r.wrapping_sub(p))
}

/// Lazy Shoup multiplication: `a · w mod p` up to one redundant multiple of
/// `p` — the result lies in `[0, 2p)` for **any** `a < 2^64` (with
/// `w_shoup = ⌊w·2^64/p⌋`: `q ≤ a·w/p` gives `r ≥ 0`, and
/// `q > a·w/p − a/2^64 − 1` gives `r < p·(a/2^64 + 1) < 2p`). The Harvey
/// NTT butterflies keep values redundant through the layer loop and correct
/// once at the end (`math/kernels.rs`).
#[inline(always)]
pub fn mul_shoup_lazy(a: u64, w: u64, w_shoup: u64, p: u64) -> u64 {
    let q = ((a as u128 * w_shoup as u128) >> 64) as u64;
    a.wrapping_mul(w).wrapping_sub(q.wrapping_mul(p))
}

/// `a + b mod m` (inputs must already be `< m`).
#[inline(always)]
pub fn add_mod(a: u64, b: u64, m: u64) -> u64 {
    let s = a.wrapping_add(b);
    if s >= m || s < a {
        s.wrapping_sub(m)
    } else {
        s
    }
}

/// `a - b mod m` (inputs must already be `< m`).
#[inline(always)]
pub fn sub_mod(a: u64, b: u64, m: u64) -> u64 {
    if a >= b {
        a - b
    } else {
        a.wrapping_sub(b).wrapping_add(m)
    }
}

/// `a^e mod m` by square-and-multiply. `m == 1` short-circuits (avoiding the
/// old `1 % m` dance); moduli below 2^32 — every NTT limb — run the whole
/// ladder on one hoisted Barrett constant instead of a `u128 %` divide per
/// squaring. Larger moduli (Miller–Rabin on arbitrary `u64`) keep `mul_mod`.
pub fn pow_mod(mut a: u64, mut e: u64, m: u64) -> u64 {
    if m == 1 {
        return 0;
    }
    a %= m;
    let mut r: u64 = 1;
    if m < (1 << 32) {
        let br = barrett_precompute(m);
        while e > 0 {
            if e & 1 == 1 {
                r = barrett_mul(r, a, m, br);
            }
            a = barrett_mul(a, a, m, br);
            e >>= 1;
        }
    } else {
        while e > 0 {
            if e & 1 == 1 {
                r = mul_mod(r, a, m);
            }
            a = mul_mod(a, a, m);
            e >>= 1;
        }
    }
    r
}

/// Modular inverse for prime `m` (Fermat). Panics if `a ≡ 0`.
pub fn inv_mod(a: u64, m: u64) -> u64 {
    assert!(a % m != 0, "inv_mod of zero");
    pow_mod(a, m - 2, m)
}

/// Deterministic Miller–Rabin, valid for all `u64` (fixed witness set).
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for p in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n % p == 0 {
            return n == p;
        }
    }
    let mut d = n - 1;
    let mut s = 0u32;
    while d & 1 == 0 {
        d >>= 1;
        s += 1;
    }
    // This witness set is proven sufficient for n < 3.3e24.
    'w: for &a in &[2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = pow_mod(a, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..s - 1 {
            x = mul_mod(x, x, n);
            if x == n - 1 {
                continue 'w;
            }
        }
        return false;
    }
    true
}

/// Smallest generator of `Z_p^*` for prime `p` (trial over small candidates).
pub fn primitive_root(p: u64) -> u64 {
    // Factor p-1 by trial division (p-1 = 2^k * odd-smooth for our primes).
    let mut factors = Vec::new();
    let mut m = p - 1;
    let mut f = 2u64;
    while f * f <= m {
        if m % f == 0 {
            factors.push(f);
            while m % f == 0 {
                m /= f;
            }
        }
        f += 1;
    }
    if m > 1 {
        factors.push(m);
    }
    'g: for g in 2..p {
        for &q in &factors {
            if pow_mod(g, (p - 1) / q, p) == 1 {
                continue 'g;
            }
        }
        return g;
    }
    unreachable!("no primitive root found for prime {p}")
}

/// A primitive `order`-th root of unity mod prime `p` (`order | p-1`).
pub fn root_of_unity(order: u64, p: u64) -> u64 {
    assert!((p - 1) % order == 0, "order {order} does not divide p-1");
    let g = primitive_root(p);
    let w = pow_mod(g, (p - 1) / order, p);
    debug_assert_eq!(pow_mod(w, order, p), 1);
    debug_assert_ne!(pow_mod(w, order / 2, p), 1);
    w
}

/// Generate `count` distinct primes `≡ 1 (mod modulus_align)` descending from
/// just below `below` (e.g. `below = 2^31` for 31-bit RNS limbs).
pub fn gen_ntt_primes(count: usize, modulus_align: u64, below: u64) -> Vec<u64> {
    let mut primes = Vec::with_capacity(count);
    let mut k = (below - 1) / modulus_align;
    while primes.len() < count {
        let candidate = k
            .checked_mul(modulus_align)
            .and_then(|v| v.checked_add(1))
            .expect("prime candidate overflow");
        if is_prime(candidate) {
            primes.push(candidate);
        }
        assert!(k > 1, "ran out of prime candidates");
        k -= 1;
    }
    primes
}

/// Centered representative of `x mod m` in `(-m/2, m/2]`, as i64 when small.
#[inline]
pub fn center(x: u64, m: u64) -> i64 {
    if x > m / 2 {
        -((m - x) as i64)
    } else {
        x as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mulmod_matches_u128() {
        let m = 0xffff_fffd_0000_0001u64 % (1u64 << 62);
        let mut x = 0x1234_5678u64;
        for _ in 0..1000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            let a = x % m;
            let b = x.rotate_left(17) % m;
            assert_eq!(mul_mod(a, b, m) as u128, (a as u128 * b as u128) % m as u128);
        }
    }

    #[test]
    fn addsub_roundtrip() {
        let m = 469762049u64;
        for a in [0u64, 1, m - 1, m / 2, 12345] {
            for b in [0u64, 1, m - 1, m / 2, 54321] {
                let s = add_mod(a, b, m);
                assert_eq!(sub_mod(s, b, m), a);
                assert!(s < m);
            }
        }
    }

    #[test]
    fn powmod_known() {
        assert_eq!(pow_mod(2, 10, 1_000_003), 1024);
        assert_eq!(pow_mod(7, 0, 11), 1);
        assert_eq!(pow_mod(5, 1_000_002, 1_000_003), 1); // Fermat
        assert_eq!(pow_mod(42, 0, 1), 0); // trivial modulus
        assert_eq!(pow_mod(42, 17, 1), 0);
        // m > 2^32 exercises the non-Barrett ladder
        let m = 0xffff_ffff_ffff_ffc5u64; // 2^64 - 59, prime
        assert_eq!(pow_mod(3, m - 1, m), 1);
    }

    #[test]
    fn fast_multiplies_match_mul_mod() {
        let p = 4294967291u64; // 2^32 - 5, the largest 32-bit prime
        let br = barrett_precompute(p);
        for a in [0u64, 1, 2, p / 2, p - 2, p - 1] {
            for w in [0u64, 1, 2, p / 2, p - 2, p - 1] {
                let want = mul_mod(a, w, p);
                assert_eq!(barrett_mul(a, w, p, br), want, "barrett a={a} w={w}");
                let ws = shoup_precompute(w, p);
                assert_eq!(mul_shoup(a, w, ws, p), want, "shoup a={a} w={w}");
                let lazy = mul_shoup_lazy(a, w, ws, p);
                assert!(lazy < 2 * p, "lazy range a={a} w={w}");
                assert_eq!(lazy % p, want, "lazy residue a={a} w={w}");
            }
        }
    }

    #[test]
    fn barrett_reduce_is_canonical_for_any_u64() {
        let p = 469762049u64;
        let br = barrett_precompute(p);
        for x in [0u64, 1, p - 1, p, p + 1, 2 * p, u64::MAX, u64::MAX - 1, 1 << 63] {
            assert_eq!(barrett_reduce(x, p, br), x % p, "x={x}");
        }
    }

    #[test]
    fn invmod_property() {
        let p = 1811939329u64; // 27*2^26+1
        for a in [1u64, 2, 3, 65537, p - 1, 123456789 % p] {
            assert_eq!(mul_mod(a, inv_mod(a, p), p), 1);
        }
    }

    #[test]
    fn primality_known_values() {
        assert!(is_prime(2));
        assert!(is_prime(469762049)); // 7 * 2^26 + 1
        assert!(is_prime(1811939329)); // 27 * 2^26 + 1
        assert!(!is_prime(1006632961)); // 31 * 32472031
        assert!(!is_prime(1));
        assert!(!is_prime(469762049 * 2));
        assert!(!is_prime((1u64 << 31) - 3));
        assert!(is_prime((1u64 << 31) - 1)); // Mersenne M31
    }

    #[test]
    fn gen_primes_are_aligned_distinct() {
        let align = 1u64 << 26;
        let ps = gen_ntt_primes(4, align, u32::MAX as u64 + 1);
        assert_eq!(ps.len(), 4);
        for (i, &p) in ps.iter().enumerate() {
            assert!(is_prime(p));
            assert_eq!(p % align, 1);
            assert!(p < (1u64 << 32));
            for &q in &ps[..i] {
                assert_ne!(p, q);
            }
        }
    }

    #[test]
    fn roots_of_unity_have_exact_order() {
        let p = 469762049u64;
        for log_order in [1u64, 4, 12, 20] {
            let order = 1u64 << log_order;
            let w = root_of_unity(order, p);
            assert_eq!(pow_mod(w, order, p), 1);
            assert_ne!(pow_mod(w, order / 2, p), 1);
        }
    }

    #[test]
    fn center_is_symmetric() {
        let m = 101u64;
        assert_eq!(center(0, m), 0);
        assert_eq!(center(50, m), 50);
        assert_eq!(center(51, m), -50);
        assert_eq!(center(100, m), -1);
    }
}
