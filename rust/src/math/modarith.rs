//! `u64` modular arithmetic via `u128` intermediates, deterministic
//! Miller–Rabin primality, and NTT-friendly prime generation.
//!
//! All BGV moduli are primes `p ≡ 1 (mod 2^26)` (DESIGN.md §2.2): this makes
//! them automatically NTT-friendly for any ring degree `N ≤ 2^25` *and*
//! guarantees `q = Π p_i ≡ 1 (mod t)` for the power-of-two plaintext modulus
//! `t ≤ 2^26`, which is what gives Glyph its noise-free LSB↔MSB switch.

/// `a * b mod m` without overflow.
#[inline(always)]
pub fn mul_mod(a: u64, b: u64, m: u64) -> u64 {
    ((a as u128 * b as u128) % m as u128) as u64
}

/// `a + b mod m` (inputs must already be `< m`).
#[inline(always)]
pub fn add_mod(a: u64, b: u64, m: u64) -> u64 {
    let s = a.wrapping_add(b);
    if s >= m || s < a {
        s.wrapping_sub(m)
    } else {
        s
    }
}

/// `a - b mod m` (inputs must already be `< m`).
#[inline(always)]
pub fn sub_mod(a: u64, b: u64, m: u64) -> u64 {
    if a >= b {
        a - b
    } else {
        a.wrapping_sub(b).wrapping_add(m)
    }
}

/// `a^e mod m` by square-and-multiply.
pub fn pow_mod(mut a: u64, mut e: u64, m: u64) -> u64 {
    let mut r: u64 = 1 % m;
    a %= m;
    while e > 0 {
        if e & 1 == 1 {
            r = mul_mod(r, a, m);
        }
        a = mul_mod(a, a, m);
        e >>= 1;
    }
    r
}

/// Modular inverse for prime `m` (Fermat). Panics if `a ≡ 0`.
pub fn inv_mod(a: u64, m: u64) -> u64 {
    assert!(a % m != 0, "inv_mod of zero");
    pow_mod(a, m - 2, m)
}

/// Deterministic Miller–Rabin, valid for all `u64` (fixed witness set).
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for p in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n % p == 0 {
            return n == p;
        }
    }
    let mut d = n - 1;
    let mut s = 0u32;
    while d & 1 == 0 {
        d >>= 1;
        s += 1;
    }
    // This witness set is proven sufficient for n < 3.3e24.
    'w: for &a in &[2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = pow_mod(a, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..s - 1 {
            x = mul_mod(x, x, n);
            if x == n - 1 {
                continue 'w;
            }
        }
        return false;
    }
    true
}

/// Smallest generator of `Z_p^*` for prime `p` (trial over small candidates).
pub fn primitive_root(p: u64) -> u64 {
    // Factor p-1 by trial division (p-1 = 2^k * odd-smooth for our primes).
    let mut factors = Vec::new();
    let mut m = p - 1;
    let mut f = 2u64;
    while f * f <= m {
        if m % f == 0 {
            factors.push(f);
            while m % f == 0 {
                m /= f;
            }
        }
        f += 1;
    }
    if m > 1 {
        factors.push(m);
    }
    'g: for g in 2..p {
        for &q in &factors {
            if pow_mod(g, (p - 1) / q, p) == 1 {
                continue 'g;
            }
        }
        return g;
    }
    unreachable!("no primitive root found for prime {p}")
}

/// A primitive `order`-th root of unity mod prime `p` (`order | p-1`).
pub fn root_of_unity(order: u64, p: u64) -> u64 {
    assert!((p - 1) % order == 0, "order {order} does not divide p-1");
    let g = primitive_root(p);
    let w = pow_mod(g, (p - 1) / order, p);
    debug_assert_eq!(pow_mod(w, order, p), 1);
    debug_assert_ne!(pow_mod(w, order / 2, p), 1);
    w
}

/// Generate `count` distinct primes `≡ 1 (mod modulus_align)` descending from
/// just below `below` (e.g. `below = 2^31` for 31-bit RNS limbs).
pub fn gen_ntt_primes(count: usize, modulus_align: u64, below: u64) -> Vec<u64> {
    let mut primes = Vec::with_capacity(count);
    let mut k = (below - 1) / modulus_align;
    while primes.len() < count {
        let candidate = k
            .checked_mul(modulus_align)
            .and_then(|v| v.checked_add(1))
            .expect("prime candidate overflow");
        if is_prime(candidate) {
            primes.push(candidate);
        }
        assert!(k > 1, "ran out of prime candidates");
        k -= 1;
    }
    primes
}

/// Centered representative of `x mod m` in `(-m/2, m/2]`, as i64 when small.
#[inline]
pub fn center(x: u64, m: u64) -> i64 {
    if x > m / 2 {
        -((m - x) as i64)
    } else {
        x as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mulmod_matches_u128() {
        let m = 0xffff_fffd_0000_0001u64 % (1u64 << 62);
        let mut x = 0x1234_5678u64;
        for _ in 0..1000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            let a = x % m;
            let b = x.rotate_left(17) % m;
            assert_eq!(mul_mod(a, b, m) as u128, (a as u128 * b as u128) % m as u128);
        }
    }

    #[test]
    fn addsub_roundtrip() {
        let m = 469762049u64;
        for a in [0u64, 1, m - 1, m / 2, 12345] {
            for b in [0u64, 1, m - 1, m / 2, 54321] {
                let s = add_mod(a, b, m);
                assert_eq!(sub_mod(s, b, m), a);
                assert!(s < m);
            }
        }
    }

    #[test]
    fn powmod_known() {
        assert_eq!(pow_mod(2, 10, 1_000_003), 1024);
        assert_eq!(pow_mod(7, 0, 11), 1);
        assert_eq!(pow_mod(5, 1_000_002, 1_000_003), 1); // Fermat
    }

    #[test]
    fn invmod_property() {
        let p = 1811939329u64; // 27*2^26+1
        for a in [1u64, 2, 3, 65537, p - 1, 123456789 % p] {
            assert_eq!(mul_mod(a, inv_mod(a, p), p), 1);
        }
    }

    #[test]
    fn primality_known_values() {
        assert!(is_prime(2));
        assert!(is_prime(469762049)); // 7 * 2^26 + 1
        assert!(is_prime(1811939329)); // 27 * 2^26 + 1
        assert!(!is_prime(1006632961)); // 31 * 32472031
        assert!(!is_prime(1));
        assert!(!is_prime(469762049 * 2));
        assert!(!is_prime((1u64 << 31) - 3));
        assert!(is_prime((1u64 << 31) - 1)); // Mersenne M31
    }

    #[test]
    fn gen_primes_are_aligned_distinct() {
        let align = 1u64 << 26;
        let ps = gen_ntt_primes(4, align, u32::MAX as u64 + 1);
        assert_eq!(ps.len(), 4);
        for (i, &p) in ps.iter().enumerate() {
            assert!(is_prime(p));
            assert_eq!(p % align, 1);
            assert!(p < (1u64 << 32));
            for &q in &ps[..i] {
                assert_ne!(p, q);
            }
        }
    }

    #[test]
    fn roots_of_unity_have_exact_order() {
        let p = 469762049u64;
        for log_order in [1u64, 4, 12, 20] {
            let order = 1u64 << log_order;
            let w = root_of_unity(order, p);
            assert_eq!(pow_mod(w, order, p), 1);
            assert_ne!(pow_mod(w, order / 2, p), 1);
        }
    }

    #[test]
    fn center_is_symmetric() {
        let m = 101u64;
        assert_eq!(center(0, m), 0);
        assert_eq!(center(50, m), 50);
        assert_eq!(center(51, m), -50);
        assert_eq!(center(100, m), -1);
    }
}
