//! RNS residue polynomials over `Z_q[X]/(X^N+1)` with `q = Π q_i`, plus the
//! small big-integer used for CRT reconstruction at decryption time.
//!
//! All BGV ciphertext arithmetic happens limb-wise on the RNS residues; the
//! only places the composite modulus `q` materializes are decryption (CRT →
//! centered → mod t) and the exact scalar maps of the cryptosystem switch.

use super::modarith::{add_mod, barrett_reduce, inv_mod, mul_mod, mul_shoup, shoup_precompute, sub_mod};
use super::ntt::NttTable;
use super::rng::GlyphRng;
use std::sync::Arc;

// --------------------------------------------------------------------------
// Minimal little-endian big unsigned integer (no vendored bigint crate).
// --------------------------------------------------------------------------

/// Little-endian base-2^64 unsigned integer. Sized for ≤ a dozen limbs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BigUintSmall {
    pub limbs: Vec<u64>,
}

impl BigUintSmall {
    pub fn zero() -> Self {
        BigUintSmall { limbs: vec![] }
    }

    pub fn from_u64(x: u64) -> Self {
        if x == 0 {
            Self::zero()
        } else {
            BigUintSmall { limbs: vec![x] }
        }
    }

    fn normalize(&mut self) {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    pub fn cmp_big(&self, other: &Self) -> std::cmp::Ordering {
        use std::cmp::Ordering::*;
        if self.limbs.len() != other.limbs.len() {
            return self.limbs.len().cmp(&other.limbs.len());
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Equal => continue,
                o => return o,
            }
        }
        Equal
    }

    pub fn add_assign(&mut self, other: &Self) {
        let n = self.limbs.len().max(other.limbs.len());
        self.limbs.resize(n, 0);
        let mut carry = 0u64;
        for i in 0..n {
            let o = other.limbs.get(i).copied().unwrap_or(0);
            let (s1, c1) = self.limbs[i].overflowing_add(o);
            let (s2, c2) = s1.overflowing_add(carry);
            self.limbs[i] = s2;
            carry = (c1 as u64) + (c2 as u64);
        }
        if carry > 0 {
            self.limbs.push(carry);
        }
    }

    /// `self -= other`; panics on underflow.
    pub fn sub_assign(&mut self, other: &Self) {
        debug_assert!(self.cmp_big(other) != std::cmp::Ordering::Less);
        let mut borrow = 0u64;
        for i in 0..self.limbs.len() {
            let o = other.limbs.get(i).copied().unwrap_or(0);
            let (d1, b1) = self.limbs[i].overflowing_sub(o);
            let (d2, b2) = d1.overflowing_sub(borrow);
            self.limbs[i] = d2;
            borrow = (b1 as u64) + (b2 as u64);
        }
        debug_assert_eq!(borrow, 0);
        self.normalize();
    }

    pub fn mul_u64(&self, x: u64) -> Self {
        if x == 0 || self.is_zero() {
            return Self::zero();
        }
        let mut out = Vec::with_capacity(self.limbs.len() + 1);
        let mut carry = 0u128;
        for &l in &self.limbs {
            let prod = l as u128 * x as u128 + carry;
            out.push(prod as u64);
            carry = prod >> 64;
        }
        if carry > 0 {
            out.push(carry as u64);
        }
        BigUintSmall { limbs: out }
    }

    /// Remainder modulo a `u64`.
    pub fn rem_u64(&self, m: u64) -> u64 {
        let mut r = 0u128;
        for &l in self.limbs.iter().rev() {
            r = ((r << 64) | l as u128) % m as u128;
        }
        r as u64
    }

    /// Low 64 bits (0 if zero).
    pub fn low_u64(&self) -> u64 {
        self.limbs.first().copied().unwrap_or(0)
    }

    /// Halve (floor), used for q/2 comparisons.
    pub fn shr1(&self) -> Self {
        let mut out = self.limbs.clone();
        let mut carry = 0u64;
        for l in out.iter_mut().rev() {
            let new_carry = *l & 1;
            *l = (*l >> 1) | (carry << 63);
            carry = new_carry;
        }
        let mut b = BigUintSmall { limbs: out };
        b.normalize();
        b
    }
}

// --------------------------------------------------------------------------
// RNS context and polynomials
// --------------------------------------------------------------------------

/// Shared precomputation for a ring `Z_q[X]/(X^N+1)`, `q = Π q_i`.
pub struct RnsContext {
    pub n: usize,
    pub primes: Vec<u64>,
    pub ntts: Vec<NttTable>,
    /// q as a big integer, and q/2 for centering.
    pub q_big: BigUintSmall,
    pub q_half: BigUintSmall,
    /// CRT reconstruction: punctured products q/q_i (big) and
    /// ((q/q_i)^{-1} mod q_i).
    pub q_over_qi: Vec<BigUintSmall>,
    pub q_over_qi_inv: Vec<u64>,
    /// q mod q_i is 0; but for scalar maps we need (q-1)/t etc. computed by
    /// callers via `scalar_to_rns`.
    pub qi_inv_pairs: Vec<Vec<u64>>, // qi_inv_pairs[i][j] = q_i^{-1} mod q_j (i<j unused half filled)
}

impl RnsContext {
    pub fn new(n: usize, primes: &[u64]) -> Arc<Self> {
        let ntts: Vec<NttTable> = primes.iter().map(|&p| NttTable::new(n, p)).collect();
        let mut q_big = BigUintSmall::from_u64(1);
        for &p in primes {
            q_big = q_big.mul_u64(p);
        }
        let q_half = q_big.shr1();
        let mut q_over_qi = Vec::with_capacity(primes.len());
        let mut q_over_qi_inv = Vec::with_capacity(primes.len());
        for (i, &pi) in primes.iter().enumerate() {
            let mut prod = BigUintSmall::from_u64(1);
            for (j, &pj) in primes.iter().enumerate() {
                if i != j {
                    prod = prod.mul_u64(pj);
                }
            }
            let inv = inv_mod(prod.rem_u64(pi), pi);
            q_over_qi.push(prod);
            q_over_qi_inv.push(inv);
        }
        let qi_inv_pairs = primes
            .iter()
            .map(|&pi| {
                primes
                    .iter()
                    .map(|&pj| if pi % pj == 0 { 0 } else { inv_mod(pi % pj, pj) })
                    .collect()
            })
            .collect();
        Arc::new(RnsContext { n, primes: primes.to_vec(), ntts, q_big, q_half, q_over_qi, q_over_qi_inv, qi_inv_pairs })
    }

    pub fn num_primes(&self) -> usize {
        self.primes.len()
    }

    /// Residues of a non-negative scalar `< q` given as big integer.
    pub fn scalar_to_rns_big(&self, x: &BigUintSmall) -> Vec<u64> {
        self.primes.iter().map(|&p| x.rem_u64(p)).collect()
    }

    /// Residues of a small signed scalar.
    pub fn scalar_to_rns_i64(&self, x: i64) -> Vec<u64> {
        self.primes
            .iter()
            .map(|&p| if x >= 0 { (x as u64) % p } else { p - ((x.unsigned_abs()) % p) })
            .collect()
    }

    /// `(q - 1) / t` as RNS residues (Δ of DESIGN.md §2.2); `t` must divide
    /// `q - 1`, which our prime alignment guarantees for `t | 2^26`.
    pub fn delta_rns(&self, t: u64) -> Vec<u64> {
        // q ≡ 1 mod t, so (q-1)/t is integral. Compute via bigint.
        let mut qm1 = self.q_big.clone();
        qm1.sub_assign(&BigUintSmall::from_u64(1));
        debug_assert_eq!(qm1.rem_u64(t), 0);
        // Divide by t (power of two): shift.
        debug_assert!(t.is_power_of_two());
        let mut d = qm1;
        for _ in 0..t.trailing_zeros() {
            d = d.shr1();
        }
        self.scalar_to_rns_big(&d)
    }

    /// CRT-reconstruct one coefficient to its centered value mod t
    /// (t a power of two). Returns a value in `[0, t)`.
    pub fn crt_coeff_mod_t(&self, residues: &[u64], t: u64) -> u64 {
        // x = Σ (x_i * inv_i mod q_i) * (q/q_i)   (mod q)
        let mut acc = BigUintSmall::zero();
        for i in 0..self.primes.len() {
            let coef = mul_mod(residues[i], self.q_over_qi_inv[i], self.primes[i]);
            acc.add_assign(&self.q_over_qi[i].mul_u64(coef));
        }
        // Reduce: acc < L * q, subtract q at most L times.
        while acc.cmp_big(&self.q_big) != std::cmp::Ordering::Less {
            acc.sub_assign(&self.q_big);
        }
        // Centered mod t.
        let mask = t - 1;
        if acc.cmp_big(&self.q_half) != std::cmp::Ordering::Greater {
            acc.low_u64() & mask
        } else {
            let mut neg = self.q_big.clone();
            neg.sub_assign(&acc);
            (t - (neg.low_u64() & mask)) & mask
        }
    }

    /// CRT-reconstruct one coefficient to a centered `i128` (requires
    /// q < 2^127; only used in tests/diagnostics at small parameters).
    pub fn crt_coeff_centered_i128(&self, residues: &[u64]) -> i128 {
        let mut acc = BigUintSmall::zero();
        for i in 0..self.primes.len() {
            let coef = mul_mod(residues[i], self.q_over_qi_inv[i], self.primes[i]);
            acc.add_assign(&self.q_over_qi[i].mul_u64(coef));
        }
        while acc.cmp_big(&self.q_big) != std::cmp::Ordering::Less {
            acc.sub_assign(&self.q_big);
        }
        let to_i128 = |b: &BigUintSmall| -> i128 {
            let lo = b.limbs.first().copied().unwrap_or(0) as i128;
            let hi = b.limbs.get(1).copied().unwrap_or(0) as i128;
            assert!(b.limbs.len() <= 2, "value too large for i128 diagnostics");
            (hi << 64) | lo
        };
        if acc.cmp_big(&self.q_half) != std::cmp::Ordering::Greater {
            to_i128(&acc)
        } else {
            let mut neg = self.q_big.clone();
            neg.sub_assign(&acc);
            -to_i128(&neg)
        }
    }
}

/// An RNS residue polynomial; `evals[i]` holds the residues mod `primes[i]`,
/// either in coefficient or NTT representation.
#[derive(Clone)]
pub struct RnsPoly {
    pub ctx: Arc<RnsContext>,
    pub res: Vec<Vec<u64>>,
    pub is_ntt: bool,
    /// Number of active RNS limbs (≤ ctx.num_primes()); modulus switching
    /// drops limbs from the back.
    pub level: usize,
}

impl RnsPoly {
    pub fn zero(ctx: &Arc<RnsContext>, level: usize) -> Self {
        RnsPoly {
            ctx: ctx.clone(),
            res: (0..level).map(|_| vec![0u64; ctx.n]).collect(),
            is_ntt: false,
            level,
        }
    }

    /// From small signed coefficients (e.g. plaintext or error polynomials).
    pub fn from_signed(ctx: &Arc<RnsContext>, coeffs: &[i64], level: usize) -> Self {
        let res = (0..level)
            .map(|i| {
                let p = ctx.primes[i];
                coeffs
                    .iter()
                    .map(|&c| if c >= 0 { (c as u64) % p } else { p - (c.unsigned_abs() % p) })
                    .collect()
            })
            .collect();
        RnsPoly { ctx: ctx.clone(), res, is_ntt: false, level }
    }

    pub fn uniform(ctx: &Arc<RnsContext>, rng: &mut GlyphRng, level: usize) -> Self {
        let res = (0..level)
            .map(|i| (0..ctx.n).map(|_| rng.uniform_mod(ctx.primes[i])).collect())
            .collect();
        RnsPoly { ctx: ctx.clone(), res, is_ntt: false, level }
    }

    pub fn n(&self) -> usize {
        self.ctx.n
    }

    pub fn to_ntt(&mut self) {
        if !self.is_ntt {
            for i in 0..self.level {
                self.ctx.ntts[i].forward(&mut self.res[i]);
            }
            self.is_ntt = true;
        }
    }

    pub fn to_coeff(&mut self) {
        if self.is_ntt {
            for i in 0..self.level {
                self.ctx.ntts[i].inverse(&mut self.res[i]);
            }
            self.is_ntt = false;
        }
    }

    fn check_compat(&self, o: &Self) {
        debug_assert_eq!(self.is_ntt, o.is_ntt, "representation mismatch");
        debug_assert_eq!(self.level, o.level, "level mismatch");
    }

    pub fn add_assign(&mut self, o: &Self) {
        self.check_compat(o);
        for i in 0..self.level {
            let p = self.ctx.primes[i];
            for (x, &y) in self.res[i].iter_mut().zip(&o.res[i]) {
                *x = add_mod(*x, y, p);
            }
        }
    }

    pub fn sub_assign(&mut self, o: &Self) {
        self.check_compat(o);
        for i in 0..self.level {
            let p = self.ctx.primes[i];
            for (x, &y) in self.res[i].iter_mut().zip(&o.res[i]) {
                *x = sub_mod(*x, y, p);
            }
        }
    }

    pub fn neg_assign(&mut self) {
        for i in 0..self.level {
            let p = self.ctx.primes[i];
            for x in self.res[i].iter_mut() {
                if *x != 0 {
                    *x = p - *x;
                }
            }
        }
    }

    /// Pointwise product (both operands must be in NTT form).
    pub fn mul_assign_ntt(&mut self, o: &Self) {
        self.check_compat(o);
        debug_assert!(self.is_ntt);
        for i in 0..self.level {
            self.ctx.ntts[i].pointwise(&mut self.res[i], &o.res[i]);
        }
    }

    /// `self += a * b` (all three in NTT form).
    pub fn mul_acc_ntt(&mut self, a: &Self, b: &Self) {
        debug_assert!(self.is_ntt && a.is_ntt && b.is_ntt);
        for i in 0..self.level {
            self.ctx.ntts[i].pointwise_acc(&mut self.res[i], &a.res[i], &b.res[i]);
        }
    }

    /// `self += a·b + c·d` (all in NTT form): the fused cross-term pass of
    /// a tensor MAC — one limb traversal instead of two `mul_acc_ntt`s.
    pub fn mul_acc2_ntt(&mut self, a: &Self, b: &Self, c: &Self, d: &Self) {
        debug_assert!(self.is_ntt && a.is_ntt && b.is_ntt && c.is_ntt && d.is_ntt);
        for i in 0..self.level {
            self.ctx.ntts[i].pointwise_acc2(&mut self.res[i], &a.res[i], &b.res[i], &c.res[i], &d.res[i]);
        }
    }

    /// Zero every residue in place (buffer reuse; no allocation).
    pub fn clear(&mut self) {
        for limb in self.res.iter_mut() {
            limb.fill(0);
        }
    }

    /// Copy residues and representation from `o` into this poly's existing
    /// buffers (shapes must match; no allocation).
    pub fn copy_from(&mut self, o: &Self) {
        debug_assert_eq!(self.level, o.level, "level mismatch in copy_from");
        for i in 0..self.level {
            self.res[i].copy_from_slice(&o.res[i]);
        }
        self.is_ntt = o.is_ntt;
    }

    /// Multiply by a scalar given as per-limb residues. The scalar is a
    /// per-limb constant, so each limb pass is a Shoup sweep through the
    /// kernel layer (one `u128 /` to precompute, zero divides in the loop).
    pub fn scalar_mul_assign(&mut self, scalar_rns: &[u64]) {
        for i in 0..self.level {
            let p = self.ctx.primes[i];
            let s = scalar_rns[i] % p;
            let s_shoup = shoup_precompute(s, p);
            self.ctx.ntts[i].scalar_mul(&mut self.res[i], s, s_shoup);
        }
    }

    /// BGV modulus switch: drop the top limb `q_ℓ`, dividing by it exactly
    /// after the CRT correction `δ ≡ self (mod q_ℓ)`, `δ ≡ 0 (mod t)`.
    /// Because every prime is ≡ 1 (mod t), the plaintext is preserved
    /// (no factor tracking needed — DESIGN.md §2.2). Coefficient form only.
    pub fn mod_switch_down(&mut self, t: u64) {
        assert!(!self.is_ntt, "mod_switch_down requires coefficient form");
        assert!(self.level >= 2, "cannot drop below one limb");
        let last = self.level - 1;
        let q_last = self.ctx.primes[last];
        debug_assert_eq!(q_last % t, 1);
        let half = q_last / 2;
        let t_half = t / 2;
        // Per remaining limb: hoist q_last mod p, q_last^{-1} mod p and
        // their Shoup companions out of the coefficient loop — the inner
        // body then runs divide-free (Barrett for the centered residues,
        // Shoup for the two constant multiplies).
        for i in 0..last {
            let p = self.ctx.primes[i];
            let br = self.ctx.ntts[i].barrett();
            let ql_red = q_last % p;
            let ql_red_shoup = shoup_precompute(ql_red, p);
            let q_last_inv = inv_mod(ql_red, p);
            let q_last_inv_shoup = shoup_precompute(q_last_inv, p);
            for j in 0..self.ctx.n {
                let d = self.res[last][j]; // δ0 = x mod q_last, in [0, q_last)
                // Center δ0, then add t·u with u ≡ -δ0 (mod t) centered so
                // that δ = δ0 + t·u ≡ 0 (mod t) (wait: we need δ ≡ 0 mod t
                // and ≡ x mod q_last; u is a multiple of q_last below).
                // Solve δ = δ0_c + q_last·v with δ ≡ 0 (mod t):
                //   v ≡ -δ0_c (mod t)      (q_last ≡ 1 mod t)
                let d_c: i64 = if d > half { d as i64 - q_last as i64 } else { d as i64 };
                let mut v = (-d_c).rem_euclid(t as i64) as u64;
                if v > t_half {
                    v = v.wrapping_sub(t); // centered representative as wrapped u64
                }
                let v_c = v as i64; // |v_c| ≤ t/2
                // x' = (x - δ) / q_last  mod p
                //    = (x - δ0_c - q_last·v_c) * q_last^{-1} mod p
                let mut num = self.res[i][j];
                // subtract δ0_c
                let d_red = if d_c >= 0 {
                    barrett_reduce(d_c as u64, p, br)
                } else {
                    p - barrett_reduce((-d_c) as u64, p, br)
                };
                num = sub_mod(num, d_red, p);
                // subtract q_last·v_c
                let v_red = if v_c >= 0 {
                    barrett_reduce(v_c as u64, p, br)
                } else {
                    p - barrett_reduce((-v_c) as u64, p, br)
                };
                num = sub_mod(num, mul_shoup(v_red, ql_red, ql_red_shoup, p), p);
                self.res[i][j] = mul_shoup(num, q_last_inv, q_last_inv_shoup, p);
            }
        }
        self.res.pop();
        self.level = last;
    }

    /// Drop to `new_level` limbs without rescaling (for key material reuse).
    pub fn truncate_level(&mut self, new_level: usize) {
        assert!(new_level <= self.level && new_level >= 1);
        self.res.truncate(new_level);
        self.level = new_level;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_small() -> Arc<RnsContext> {
        // Primes ≡ 1 mod 2^26 (≥ the test t and 2N alignment).
        let primes = crate::math::modarith::gen_ntt_primes(3, 1 << 26, 1 << 32);
        RnsContext::new(64, &primes)
    }

    #[test]
    fn bigint_add_sub_mul_roundtrip() {
        let a = BigUintSmall::from_u64(u64::MAX).mul_u64(u64::MAX);
        let mut b = a.clone();
        b.add_assign(&BigUintSmall::from_u64(12345));
        b.sub_assign(&BigUintSmall::from_u64(12345));
        assert_eq!(a, b);
        assert_eq!(BigUintSmall::from_u64(100).rem_u64(7), 2);
        let big = BigUintSmall::from_u64(1).mul_u64(u64::MAX).mul_u64(13);
        assert_eq!(big.rem_u64(13), 0);
    }

    #[test]
    fn bigint_shr1_halves() {
        let a = BigUintSmall { limbs: vec![1, 1] }; // 2^64 + 1
        let h = a.shr1(); // 2^63
        assert_eq!(h.limbs, vec![1u64 << 63]);
    }

    #[test]
    fn crt_roundtrip_small_values() {
        let ctx = ctx_small();
        let t = 1u64 << 16;
        for v in [0i64, 1, -1, 12345, -54321, (1 << 15) - 1, -(1 << 15)] {
            let rns = ctx.scalar_to_rns_i64(v);
            let got = ctx.crt_coeff_mod_t(&rns, t);
            let want = (v.rem_euclid(t as i64)) as u64;
            assert_eq!(got, want, "v={v}");
            let centered = ctx.crt_coeff_centered_i128(&rns);
            assert_eq!(centered, v as i128, "v={v}");
        }
    }

    #[test]
    fn delta_times_t_is_minus_one_mod_q() {
        let ctx = ctx_small();
        let t = 1u64 << 16;
        let delta = ctx.delta_rns(t);
        // Δ·t ≡ q-1 ≡ -1 (mod every prime)
        for (i, &p) in ctx.primes.iter().enumerate() {
            assert_eq!(mul_mod(delta[i], t % p, p), p - 1);
        }
    }

    #[test]
    fn poly_add_sub_neg() {
        let ctx = ctx_small();
        let mut rng = GlyphRng::new(1);
        let a = RnsPoly::uniform(&ctx, &mut rng, 3);
        let b = RnsPoly::uniform(&ctx, &mut rng, 3);
        let mut c = a.clone();
        c.add_assign(&b);
        c.sub_assign(&b);
        for i in 0..3 {
            assert_eq!(c.res[i], a.res[i]);
        }
        let mut d = a.clone();
        d.neg_assign();
        d.add_assign(&a);
        assert!(d.res.iter().all(|r| r.iter().all(|&x| x == 0)));
    }

    #[test]
    fn ntt_mul_matches_schoolbook_per_limb() {
        let ctx = ctx_small();
        let mut rng = GlyphRng::new(2);
        let a = RnsPoly::uniform(&ctx, &mut rng, 2);
        let b = RnsPoly::uniform(&ctx, &mut rng, 2);
        let mut fa = a.clone();
        let mut fb = b.clone();
        fa.to_ntt();
        fb.to_ntt();
        fa.mul_assign_ntt(&fb);
        fa.to_coeff();
        for i in 0..2 {
            let want = crate::math::ntt::negacyclic_mul_naive(&a.res[i], &b.res[i], ctx.primes[i]);
            assert_eq!(fa.res[i], want);
        }
    }

    #[test]
    fn mod_switch_preserves_plaintext_and_shrinks_noise() {
        // phase = m + t*e with |t*e| << q; after dropping a limb the phase
        // must still be ≡ m (mod t) and roughly e/q_last in magnitude.
        let ctx = ctx_small();
        let t = 1u64 << 8;
        let n = ctx.n;
        let mut coeffs = vec![0i64; n];
        let mut rng = GlyphRng::new(3);
        for c in coeffs.iter_mut() {
            let m = (rng.uniform_mod(t) as i64) - (t as i64 / 2);
            let e = rng.gaussian_i64(1e6); // sizeable noise
            *c = m + t as i64 * e;
        }
        let mut poly = RnsPoly::from_signed(&ctx, &coeffs, 3);
        poly.mod_switch_down(t);
        assert_eq!(poly.level, 2);
        for j in 0..n {
            let res: Vec<u64> = (0..2).map(|i| poly.res[i][j]).collect();
            let sub_ctx = RnsContext::new(ctx.n, &ctx.primes[..2]);
            let got = sub_ctx.crt_coeff_mod_t(&res, t);
            let want = coeffs[j].rem_euclid(t as i64) as u64;
            assert_eq!(got, want, "j={j}");
            // noise shrank by ~q_last
            let centered = sub_ctx.crt_coeff_centered_i128(&res);
            assert!(centered.unsigned_abs() < (1 << 22), "j={j} centered={centered}");
        }
    }
}
