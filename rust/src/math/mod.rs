//! Mathematical substrate shared by both cryptosystems.
//!
//! * [`modarith`] — `u64` modular arithmetic (mul/pow/inv via `u128`),
//!   Barrett/Shoup fast-multiply primitives, deterministic Miller–Rabin,
//!   NTT-prime search.
//! * [`kernels`] — the pluggable ring-arithmetic kernel layer: every hot
//!   inner loop (NTT butterflies, pointwise passes, FFT stages, gadget
//!   decomposition, key-switch AXPY) behind the [`RingKernels`] trait, with
//!   a scalar reference and a vectorized lazy-reduction implementation
//!   selected via `GLYPH_KERNELS=scalar|simd` (default `simd`).
//! * [`ntt`] — in-place negacyclic number-theoretic transform over an NTT
//!   prime (the BGV polynomial-multiplication hot path).
//! * [`fft`] — twisted complex-f64 FFT for negacyclic torus32 polynomial
//!   products (the TFHE blind-rotation hot path).
//! * [`poly`] — RNS residue polynomials and the small big-integer used for
//!   CRT reconstruction at decryption.
//! * [`rng`] — xoshiro256++ PRNG plus uniform/ternary/discrete-Gaussian
//!   samplers (the vendored crate set has no `rand`, so we own this).

pub mod fft;
pub mod kernels;
pub mod modarith;
pub mod ntt;
pub mod poly;
pub mod rng;

pub use fft::FftTable;
pub use kernels::{default_kernels, scalar_kernels, simd_kernels, RingKernels};
pub use modarith::{inv_mod, mul_mod, pow_mod};
pub use ntt::NttTable;
pub use poly::{BigUintSmall, RnsContext, RnsPoly};
pub use rng::GlyphRng;
