//! Mathematical substrate shared by both cryptosystems.
//!
//! * [`modarith`] — `u64` modular arithmetic (mul/pow/inv via `u128`),
//!   deterministic Miller–Rabin, NTT-prime search.
//! * [`ntt`] — in-place negacyclic number-theoretic transform over an NTT
//!   prime (the BGV polynomial-multiplication hot path).
//! * [`fft`] — twisted complex-f64 FFT for negacyclic torus32 polynomial
//!   products (the TFHE blind-rotation hot path).
//! * [`poly`] — RNS residue polynomials and the small big-integer used for
//!   CRT reconstruction at decryption.
//! * [`rng`] — xoshiro256++ PRNG plus uniform/ternary/discrete-Gaussian
//!   samplers (the vendored crate set has no `rand`, so we own this).

pub mod fft;
pub mod modarith;
pub mod ntt;
pub mod poly;
pub mod rng;

pub use modarith::{inv_mod, mul_mod, pow_mod};
pub use ntt::NttTable;
pub use poly::{BigUintSmall, RnsContext, RnsPoly};
pub use rng::GlyphRng;
