//! Deterministic PRNG + lattice-crypto samplers.
//!
//! The vendored crate set has no `rand`, so we carry a small xoshiro256++
//! implementation (public-domain algorithm by Blackman & Vigna) plus the
//! three samplers FHE needs: uniform torus/modular, ternary secrets, and a
//! rounded-Gaussian error sampler (Box–Muller). Determinism by explicit seed
//! keeps every test and benchmark reproducible.

/// xoshiro256++ PRNG.
#[derive(Clone)]
pub struct GlyphRng {
    s: [u64; 4],
}

impl GlyphRng {
    /// Seed via SplitMix64 expansion (zero seed is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        GlyphRng { s: [next(), next(), next(), next()] }
    }

    /// The raw generator state — the *cursor* persisted by checkpoints so a
    /// resumed run continues the exact draw sequence ([`Self::from_state`]).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at a previously captured cursor.
    pub fn from_state(s: [u64; 4]) -> Self {
        GlyphRng { s }
    }

    /// Nondeterministic seed for key generation in the examples/CLI.
    pub fn from_entropy() -> Self {
        use std::time::{SystemTime, UNIX_EPOCH};
        let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap();
        let pid = std::process::id() as u64;
        Self::new(t.as_nanos() as u64 ^ (pid << 32) ^ (&t as *const _ as u64))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, m)` by rejection (unbiased).
    pub fn uniform_mod(&mut self, m: u64) -> u64 {
        debug_assert!(m > 0);
        let zone = u64::MAX - (u64::MAX % m);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % m;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self, sigma: f64) -> f64 {
        let u1 = loop {
            let u = self.uniform_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.uniform_f64();
        sigma * (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Rounded Gaussian as a signed integer.
    pub fn gaussian_i64(&mut self, sigma: f64) -> i64 {
        self.gaussian(sigma).round() as i64
    }

    /// Ternary secret coefficient in {-1, 0, 1} (uniform).
    pub fn ternary(&mut self) -> i64 {
        (self.uniform_mod(3) as i64) - 1
    }

    /// Uniform torus32 element.
    #[inline]
    pub fn torus32(&mut self) -> u32 {
        self.next_u32()
    }

    /// Gaussian torus32 noise with standard deviation `alpha` (fraction of
    /// the torus, as in the TFHE papers).
    pub fn torus32_gaussian(&mut self, alpha: f64) -> u32 {
        let e = self.gaussian(alpha); // in torus units
        (e * 2f64.powi(32)).round() as i64 as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = GlyphRng::new(42);
        let mut b = GlyphRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = GlyphRng::new(1);
        let mut b = GlyphRng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mod_in_range_and_covers() {
        let mut r = GlyphRng::new(3);
        let m = 17u64;
        let mut seen = [false; 17];
        for _ in 0..2000 {
            let v = r.uniform_mod(m);
            assert!(v < m);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = GlyphRng::new(5);
        let sigma = 3.2;
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian(sigma)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((var.sqrt() - sigma).abs() < 0.15, "std {}", var.sqrt());
    }

    #[test]
    fn ternary_is_balanced() {
        let mut r = GlyphRng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..30000 {
            counts[(r.ternary() + 1) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn torus_gaussian_is_small() {
        let mut r = GlyphRng::new(11);
        // alpha = 2^-25: samples must stay well below 2^-15 of the torus.
        for _ in 0..1000 {
            let e = r.torus32_gaussian(2f64.powi(-25)) as i32;
            assert!((e as i64).abs() < (1 << 17), "{e}");
        }
    }
}
