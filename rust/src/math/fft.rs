//! Twisted complex-f64 FFT for negacyclic torus32 polynomial products.
//!
//! TFHE's blind rotation multiplies torus32 polynomials in
//! `T_N[X]/(X^N + 1)` by small integer (gadget-decomposed) polynomials. We
//! evaluate both at the primitive 2N-th roots of unity `ω^{4m+1}`
//! (`m = 0..N/2`), which a single size-N/2 complex FFT reaches after the
//! folding `z_j = (a_j + i·a_{j+N/2})·ω^j`. One negacyclic product is then
//! two forward FFTs, a pointwise pass and one inverse FFT of size N/2.
//!
//! Precision budget: gadget digits are `|d| ≤ Bg/2 ≤ 2^6`, torus coefficients
//! centered `|c| ≤ 2^31`; a full TRGSW external-product accumulation of
//! `(k+1)·l = 6` negacyclic products therefore has coefficients bounded by
//! `6·N·2^6·2^31 ≈ 2^49.6 < 2^53` at `N = 1024`, so the f64 pipeline is
//! exact at the integer level up to FFT rounding noise of a few torus ulps.
//! This budget is machine-checked (extreme digits, extreme coefficients) in
//! `tests/fft_precision.rs`, not just asserted here.
//!
//! The stage loop and the frequency-domain MAC dispatch through the
//! pluggable [`RingKernels`] layer (`math/kernels.rs`). Twiddles are stored
//! as structure-of-arrays re/im slabs so the vectorized kernel streams them
//! as unit-stride f64 lanes; both kernel sets evaluate the identical
//! expression tree (no FMA contraction), so results are bit-identical —
//! enforced by `tests/kernel_equivalence.rs`.

use super::kernels::{default_kernels, RingKernels};

/// Minimal complex type (no vendored `num-complex`). `repr(C)` pins the
/// (re, im) layout the kernel layer's split-slab loops assume.
#[repr(C)]
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cplx {
    pub re: f64,
    pub im: f64,
}

impl Cplx {
    #[inline(always)]
    pub fn new(re: f64, im: f64) -> Self {
        Cplx { re, im }
    }
    #[inline(always)]
    pub fn add(self, o: Cplx) -> Cplx {
        Cplx::new(self.re + o.re, self.im + o.im)
    }
    #[inline(always)]
    pub fn sub(self, o: Cplx) -> Cplx {
        Cplx::new(self.re - o.re, self.im - o.im)
    }
    #[inline(always)]
    pub fn mul(self, o: Cplx) -> Cplx {
        Cplx::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }
    #[inline(always)]
    pub fn mul_add_acc(self, o: Cplx, acc: &mut Cplx) {
        acc.re += self.re * o.re - self.im * o.im;
        acc.im += self.re * o.im + self.im * o.re;
    }
}

/// FFT plan for negacyclic products in `R[X]/(X^N+1)`, N a power of two ≥ 4.
pub struct TorusFft {
    /// Ring degree N.
    pub n: usize,
    /// FFT size M = N/2.
    m: usize,
    /// e^{+2πi k/M} twiddles, per-stage layout, split re/im slabs
    /// (structure-of-arrays for the vectorized stage kernel).
    tw_re: Vec<f64>,
    tw_im: Vec<f64>,
    /// Twist ω^j = e^{iπ j/N}, j in 0..M.
    twist: Vec<Cplx>,
    /// Inverse twist ω^{-j} / M (folding the 1/M scale in).
    inv_twist: Vec<Cplx>,
    /// Scratch bit-reversal permutation.
    bitrev: Vec<usize>,
    /// Kernel set the stage loop and MAC dispatch through.
    kernels: &'static dyn RingKernels,
}

/// The construction-time name the switch/bench layers use; same plan type.
pub type FftTable = TorusFft;

impl TorusFft {
    /// Plan with the process-default kernel set.
    pub fn new(n: usize) -> Self {
        Self::with_kernels(n, default_kernels())
    }

    /// Plan pinned to an explicit kernel set (conformance tests / benches).
    pub fn with_kernels(n: usize, kernels: &'static dyn RingKernels) -> Self {
        assert!(n.is_power_of_two() && n >= 4);
        let m = n / 2;
        let bits = m.trailing_zeros();
        // Per-stage twiddles: stage with half-size h uses e^{2πi k/(2h)}.
        let mut tw_re = Vec::with_capacity(m.max(1));
        let mut tw_im = Vec::with_capacity(m.max(1));
        let mut h = 1;
        while h < m {
            for k in 0..h {
                let ang = std::f64::consts::PI * (k as f64) / (h as f64);
                tw_re.push(ang.cos());
                tw_im.push(ang.sin());
            }
            h <<= 1;
        }
        let twist = (0..m)
            .map(|j| {
                let ang = std::f64::consts::PI * (j as f64) / (n as f64);
                Cplx::new(ang.cos(), ang.sin())
            })
            .collect();
        let inv_twist = (0..m)
            .map(|j| {
                let ang = -std::f64::consts::PI * (j as f64) / (n as f64);
                let s = 1.0 / m as f64;
                Cplx::new(ang.cos() * s, ang.sin() * s)
            })
            .collect();
        let bitrev = (0..m).map(|i| i.reverse_bits() >> (usize::BITS - bits.max(1)) as usize).collect();
        TorusFft { n, m, tw_re, tw_im, twist, inv_twist, bitrev, kernels }
    }

    /// The kernel set this plan dispatches through.
    #[inline]
    pub fn kernels(&self) -> &'static dyn RingKernels {
        self.kernels
    }

    /// In-place size-M DFT with e^{+2πi/M} convention (DIT, natural in /
    /// natural out via pre-permutation).
    fn fft_inplace(&self, a: &mut [Cplx]) {
        let m = self.m;
        if m == 1 {
            return;
        }
        // Bit-reverse permute.
        for i in 0..m {
            let j = self.bitrev[i];
            if i < j {
                a.swap(i, j);
            }
        }
        self.kernels.fft_stages(&self.tw_re, &self.tw_im, a);
    }

    /// Inverse of [`fft_inplace`] *without* the 1/M scale (the scale lives in
    /// `inv_twist`): conjugate → forward → conjugate.
    fn ifft_inplace(&self, a: &mut [Cplx]) {
        for x in a.iter_mut() {
            x.im = -x.im;
        }
        self.fft_inplace(a);
        for x in a.iter_mut() {
            x.im = -x.im;
        }
    }

    /// FFT lane length M = N/2 (the size of every frequency-domain buffer).
    #[inline]
    pub fn lanes(&self) -> usize {
        self.m
    }

    /// Forward transform of a torus32 polynomial (coefficients centered).
    pub fn forward_torus(&self, poly: &[u32]) -> Vec<Cplx> {
        let mut z = vec![Cplx::default(); self.m];
        self.forward_torus_into(poly, &mut z);
        z
    }

    /// Allocation-free [`Self::forward_torus`]: writes the M frequency
    /// coefficients into `out` (bit-identical to the allocating version).
    pub fn forward_torus_into(&self, poly: &[u32], out: &mut [Cplx]) {
        debug_assert_eq!(poly.len(), self.n);
        debug_assert_eq!(out.len(), self.m);
        let m = self.m;
        for j in 0..m {
            let re = poly[j] as i32 as f64;
            let im = poly[j + m] as i32 as f64;
            out[j] = Cplx::new(re, im).mul(self.twist[j]);
        }
        self.fft_inplace(out);
    }

    /// Forward transform of a small integer polynomial (e.g. gadget digits).
    pub fn forward_int(&self, poly: &[i32]) -> Vec<Cplx> {
        let mut z = vec![Cplx::default(); self.m];
        self.forward_int_into(poly, &mut z);
        z
    }

    /// Allocation-free [`Self::forward_int`]: writes the M frequency
    /// coefficients into `out` (bit-identical to the allocating version).
    pub fn forward_int_into(&self, poly: &[i32], out: &mut [Cplx]) {
        debug_assert_eq!(poly.len(), self.n);
        debug_assert_eq!(out.len(), self.m);
        let m = self.m;
        for j in 0..m {
            out[j] = Cplx::new(poly[j] as f64, poly[j + m] as f64).mul(self.twist[j]);
        }
        self.fft_inplace(out);
    }

    /// Pointwise multiply-accumulate in the FFT domain.
    pub fn mul_acc(&self, a: &[Cplx], b: &[Cplx], acc: &mut [Cplx]) {
        debug_assert_eq!(a.len(), self.m);
        self.kernels.fft_mul_acc(a, b, acc);
    }

    /// Inverse transform; result coefficients rounded and wrapped to torus32,
    /// added into `out`.
    pub fn inverse_add_to_torus(&self, freq: &[Cplx], out: &mut [u32]) {
        let mut z = freq.to_vec();
        self.inverse_add_to_torus_inplace(&mut z, out);
    }

    /// Allocation-free [`Self::inverse_add_to_torus`] that consumes `freq`
    /// in place (the caller's accumulator is clobbered — it is scratch).
    pub fn inverse_add_to_torus_inplace(&self, freq: &mut [Cplx], out: &mut [u32]) {
        debug_assert_eq!(out.len(), self.n);
        debug_assert_eq!(freq.len(), self.m);
        let m = self.m;
        self.ifft_inplace(freq);
        for j in 0..m {
            let c = freq[j].mul(self.inv_twist[j]);
            out[j] = out[j].wrapping_add(c.re.round() as i64 as u32);
            out[j + m] = out[j + m].wrapping_add(c.im.round() as i64 as u32);
        }
    }

    /// Convenience: full negacyclic product `int_poly * torus_poly`.
    pub fn negacyclic_mul_int_torus(&self, ints: &[i32], torus: &[u32]) -> Vec<u32> {
        let fa = self.forward_int(ints);
        let fb = self.forward_torus(torus);
        let mut acc = vec![Cplx::default(); self.m];
        self.mul_acc(&fa, &fb, &mut acc);
        let mut out = vec![0u32; self.n];
        self.inverse_add_to_torus(&acc, &mut out);
        out
    }
}

/// Reference schoolbook negacyclic `int × torus32` product (wrapping).
pub fn negacyclic_mul_int_torus_naive(ints: &[i32], torus: &[u32]) -> Vec<u32> {
    let n = ints.len();
    let mut out = vec![0u32; n];
    for i in 0..n {
        if ints[i] == 0 {
            continue;
        }
        for j in 0..n {
            let prod = (ints[i] as i64).wrapping_mul(torus[j] as i32 as i64) as u32;
            let k = i + j;
            if k < n {
                out[k] = out[k].wrapping_add(prod);
            } else {
                out[k - n] = out[k - n].wrapping_sub(prod);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::kernels::{scalar_kernels, simd_kernels};
    use crate::math::rng::GlyphRng;

    fn torus_dist(a: u32, b: u32) -> u32 {
        let d = a.wrapping_sub(b);
        d.min(d.wrapping_neg())
    }

    #[test]
    fn matches_schoolbook_small_ints() {
        for n in [8usize, 64, 1024] {
            let fft = TorusFft::new(n);
            let mut rng = GlyphRng::new(n as u64 + 1);
            let ints: Vec<i32> = (0..n).map(|_| (rng.uniform_mod(127) as i32) - 63).collect();
            let torus: Vec<u32> = (0..n).map(|_| rng.torus32()).collect();
            let fast = fft.negacyclic_mul_int_torus(&ints, &torus);
            let slow = negacyclic_mul_int_torus_naive(&ints, &torus);
            for i in 0..n {
                // f64 rounding may differ by a few ulps of the torus.
                assert!(torus_dist(fast[i], slow[i]) < 1 << 6, "n={n} i={i}: {} vs {}", fast[i], slow[i]);
            }
        }
    }

    #[test]
    fn scalar_and_simd_plans_are_bit_identical() {
        for n in [8usize, 64, 512] {
            let fs = TorusFft::with_kernels(n, scalar_kernels());
            let fv = TorusFft::with_kernels(n, simd_kernels());
            let mut rng = GlyphRng::new(0xfeed ^ n as u64);
            let ints: Vec<i32> = (0..n).map(|_| (rng.uniform_mod(129) as i32) - 64).collect();
            let torus: Vec<u32> = (0..n).map(|_| rng.torus32()).collect();
            // frequency-domain buffers must match to the last f64 bit
            let zs = fs.forward_torus(&torus);
            let zv = fv.forward_torus(&torus);
            for (a, b) in zs.iter().zip(&zv) {
                assert_eq!(a.re.to_bits(), b.re.to_bits(), "n={n}");
                assert_eq!(a.im.to_bits(), b.im.to_bits(), "n={n}");
            }
            // ...and so must the rounded torus output of a full product
            assert_eq!(
                fs.negacyclic_mul_int_torus(&ints, &torus),
                fv.negacyclic_mul_int_torus(&ints, &torus),
                "n={n}"
            );
        }
    }

    #[test]
    fn multiply_by_one_is_identity() {
        let n = 256;
        let fft = TorusFft::new(n);
        let mut one = vec![0i32; n];
        one[0] = 1;
        let mut rng = GlyphRng::new(2);
        let torus: Vec<u32> = (0..n).map(|_| rng.torus32()).collect();
        let out = fft.negacyclic_mul_int_torus(&one, &torus);
        for i in 0..n {
            assert!(torus_dist(out[i], torus[i]) < 4, "i={i}");
        }
    }

    #[test]
    fn multiply_by_x_rotates_negacyclically() {
        let n = 64;
        let fft = TorusFft::new(n);
        let mut x = vec![0i32; n];
        x[1] = 1;
        let mut torus = vec![0u32; n];
        torus[n - 1] = 1 << 30;
        let out = fft.negacyclic_mul_int_torus(&x, &torus);
        // X * X^{N-1} = -1: coefficient 0 becomes -2^30.
        assert!(torus_dist(out[0], (1u32 << 30).wrapping_neg()) < 4);
    }

    #[test]
    fn accumulation_precision_external_product_scale() {
        // Worst-case magnitude of a TRGSW external product: 6 accumulated
        // products of |d|<=64 by full-torus polys must stay exact-ish.
        let n = 1024;
        let fft = TorusFft::new(n);
        let mut rng = GlyphRng::new(77);
        let mut acc = vec![Cplx::default(); n / 2];
        let mut ref_out = vec![0u32; n];
        for _ in 0..6 {
            let ints: Vec<i32> = (0..n).map(|_| (rng.uniform_mod(129) as i32) - 64).collect();
            let torus: Vec<u32> = (0..n).map(|_| rng.torus32()).collect();
            let fa = fft.forward_int(&ints);
            let fb = fft.forward_torus(&torus);
            fft.mul_acc(&fa, &fb, &mut acc);
            let slow = negacyclic_mul_int_torus_naive(&ints, &torus);
            for i in 0..n {
                ref_out[i] = ref_out[i].wrapping_add(slow[i]);
            }
        }
        let mut fast = vec![0u32; n];
        fft.inverse_add_to_torus(&acc, &mut fast);
        for i in 0..n {
            assert!(torus_dist(fast[i], ref_out[i]) < 1 << 10, "i={i}");
        }
    }
}
