//! Noise refresh service — the documented substitution for BGV
//! bootstrapping (DESIGN.md §5).
//!
//! HElib's recryption ("bootstrapping") resets a ciphertext's noise without
//! the secret key. Implementing recryption is out of scope for this
//! reproduction (it is orthogonal to Glyph's contribution), so the same
//! *interface* is provided by a key-holding authority that decrypts and
//! re-encrypts. Every invocation is counted so the cost model can charge it
//! at HElib-reported recrypt latencies, and the trust-model caveat is in the
//! README. All call sites go through the [`NoiseRefresher`] trait, so a real
//! recryption could be dropped in without touching the training stack.

use super::ciphertext::BgvCiphertext;
use super::keys::{BgvContext, BgvSecretKey};
use crate::math::rng::GlyphRng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Anything that can reset a ciphertext's noise (and raise it back to the
/// top level).
pub trait NoiseRefresher: Send + Sync {
    /// Fresh re-encryption of the same plaintext at top level.
    fn refresh(&self, ct: &BgvCiphertext) -> BgvCiphertext;
    /// Number of refreshes performed so far (for HOP accounting).
    fn refresh_count(&self) -> usize;
}

/// The key-holding refresh authority.
pub struct KeyAuthority {
    pub sk: Arc<BgvSecretKey>,
    rng: Mutex<GlyphRng>,
    count: AtomicUsize,
}

impl KeyAuthority {
    pub fn new(sk: Arc<BgvSecretKey>, rng: GlyphRng) -> Arc<Self> {
        Arc::new(KeyAuthority { sk, rng: Mutex::new(rng), count: AtomicUsize::new(0) })
    }

    pub fn ctx(&self) -> &Arc<BgvContext> {
        &self.sk.ctx
    }

    /// The authority's RNG cursor. Checkpoints persist it so that a resumed
    /// run's re-encryption noise draws replay bit-identically.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.lock().unwrap().state()
    }

    /// Reposition the authority's RNG cursor (checkpoint restore).
    pub fn restore_rng_state(&self, s: [u64; 4]) {
        *self.rng.lock().unwrap() = GlyphRng::from_state(s);
    }

    /// Overwrite the refresh counter (checkpoint restore).
    pub fn restore_count(&self, count: usize) {
        self.count.store(count, Ordering::Relaxed);
    }
}

impl NoiseRefresher for KeyAuthority {
    fn refresh(&self, ct: &BgvCiphertext) -> BgvCiphertext {
        self.count.fetch_add(1, Ordering::Relaxed);
        let pt = self.sk.decrypt(ct);
        let mut rng = self.rng.lock().unwrap();
        self.sk.encrypt(&pt, &mut rng)
    }

    fn refresh_count(&self) -> usize {
        self.count.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bgv::encoding::Plaintext;
    use crate::bgv::keys::RelinKey;
    use crate::bgv::params::BgvParams;

    #[test]
    fn refresh_resets_noise_and_level() {
        let ctx = BgvContext::new(BgvParams::test_params());
        let mut rng = GlyphRng::new(55);
        let sk = Arc::new(BgvSecretKey::generate(&ctx, &mut rng));
        let rlk = RelinKey::generate(&sk, &mut rng);
        let auth = KeyAuthority::new(sk.clone(), GlyphRng::new(56));

        let pt = Plaintext::encode_batch(&[21, -2], &ctx.params);
        let mut ct = sk.encrypt(&pt, &mut rng);
        let other = sk.encrypt(&Plaintext::encode_scalar(3, &ctx.params), &mut rng);
        ct.mul_assign(&other, &rlk, &ctx);
        ct.mod_switch_down(&ctx);
        let noisy = sk.noise_magnitude(&ct);

        let fresh = auth.refresh(&ct);
        assert_eq!(fresh.level, ctx.top_level());
        assert_eq!(sk.decrypt(&fresh).decode_batch(2), vec![63, -6]);
        assert!(sk.noise_magnitude(&fresh) < noisy * (1 << 16), "sanity");
        assert_eq!(auth.refresh_count(), 1);
    }
}
