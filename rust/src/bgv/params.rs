//! BGV parameter profiles.

use crate::math::modarith::gen_ntt_primes;
use crate::math::poly::RnsContext;
use std::sync::Arc;

/// Parameters for one BGV instantiation.
#[derive(Clone)]
pub struct BgvParams {
    /// Ring degree N (power of two). Batch capacity = N.
    pub n: usize,
    /// RNS primes, most significant last (modulus switching drops from the
    /// back). All ≡ 1 (mod `prime_align`).
    pub primes: Vec<u64>,
    /// Plaintext modulus t (power of two).
    pub t: u64,
    /// Error standard deviation.
    pub sigma: f64,
    /// Alignment the primes were generated with (2^26 for the MAC profile).
    pub prime_align: u64,
}

impl BgvParams {
    /// MAC profile (paper's Glyph layers): N = 2048, t = 2^26, 3 limbs.
    /// Depth budget: one MultCC + relin + the switch's scalar maps between
    /// refreshes — exactly Glyph's per-layer usage.
    pub fn mac_params() -> Self {
        let align = 1u64 << 26;
        BgvParams {
            n: 2048,
            primes: gen_ntt_primes(3, align, 1u64 << 32),
            t: 1 << 26,
            sigma: 3.2,
            prime_align: align,
        }
    }

    /// FHESGD-baseline table-lookup profile: t = 2 bit-slices, deep chain
    /// for the depth-8 indicator tree of an 8-bit lookup.
    pub fn tlu_params() -> Self {
        let align = 1u64 << 26; // same pool; only ≥ 2N alignment is required
        BgvParams {
            n: 2048,
            primes: gen_ntt_primes(9, align, 1u64 << 32),
            t: 2,
            sigma: 3.2,
            prime_align: align,
        }
    }

    /// Fast unit-test profile.
    pub fn test_params() -> Self {
        let align = 1u64 << 26;
        BgvParams {
            n: 256,
            primes: gen_ntt_primes(3, align, 1u64 << 32),
            t: 1 << 16,
            sigma: 3.2,
            prime_align: align,
        }
    }

    /// Test profile for the t=2 lookup machinery.
    pub fn test_tlu_params() -> Self {
        let align = 1u64 << 26;
        BgvParams {
            n: 256,
            primes: gen_ntt_primes(9, align, 1u64 << 32),
            t: 2,
            sigma: 3.2,
            prime_align: align,
        }
    }

    pub fn levels(&self) -> usize {
        self.primes.len()
    }

    /// Build the shared RNS context.
    pub fn context(&self) -> Arc<RnsContext> {
        for &p in &self.primes {
            assert_eq!(p % (2 * self.n as u64), 1, "prime {p} not NTT-friendly for N={}", self.n);
            assert_eq!(p % self.t, 1, "prime {p} ≢ 1 mod t (breaks plaintext-preserving modswitch)");
        }
        assert!(self.t.is_power_of_two());
        RnsContext::new(self.n, &self.primes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_are_consistent() {
        for p in [BgvParams::mac_params(), BgvParams::tlu_params(), BgvParams::test_params()] {
            let ctx = p.context(); // asserts alignment internally
            assert_eq!(ctx.n, p.n);
            assert_eq!(ctx.num_primes(), p.levels());
        }
    }

    #[test]
    fn mac_profile_headroom_for_8bit_macs() {
        // 8-bit values × 8-bit weights × fan-in 1568 must fit in t.
        let p = BgvParams::mac_params();
        let max_mac: u64 = 127 * 127 * 1568;
        assert!(max_mac < p.t / 2, "max MAC {max_mac} vs t/2 {}", p.t / 2);
    }
}
