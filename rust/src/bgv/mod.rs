//! BGV — the vectorial-arithmetic-friendly cryptosystem Glyph uses for
//! MAC-heavy layers (FC / conv / pooling / batch-norm).
//!
//! This is a from-scratch RNS leveled BGV over `Z_q[X]/(X^N+1)`:
//!
//! * plaintext modulus `t` is a power of two (default `2^26`), plaintexts are
//!   **batch-in-coefficients** packed (DESIGN.md §2.1): coefficient `b` of a
//!   value ciphertext holds sample `b` of the mini-batch, and weights are
//!   constant polynomials, so MultCC/MultCP are exactly the paper's
//!   slot-wise SIMD MACs with no rotations anywhere;
//! * every RNS prime is ≡ 1 (mod 2^26), so `q ≡ 1 (mod t)`: modulus
//!   switching preserves plaintexts without factor tracking, and the
//!   LSB↔MSB maps of the cryptosystem switch are exact scalar
//!   multiplications (DESIGN.md §2.2);
//! * relinearization uses RNS decomposition key switching;
//! * [`refresh`] substitutes HElib-style recryption behind a trait
//!   (documented substitution — DESIGN.md §5);
//! * [`lut`] is the bit-sliced homomorphic table lookup used by the FHESGD
//!   baseline's sigmoid activations (t = 2 profile).

pub mod ciphertext;
pub mod encoding;
pub mod keys;
pub mod lut;
pub mod params;
pub mod refresh;

pub use ciphertext::{mac_row, BgvCiphertext, BgvScratch, MacTerm};
pub use encoding::{CachedPlaintext, EncodingError, Plaintext};
pub use keys::{BgvContext, BgvSecretKey, RelinKey};
pub use params::BgvParams;
pub use refresh::{KeyAuthority, NoiseRefresher};
