//! BGV contexts and key material.
//!
//! Ciphertexts live at a *level* ℓ = number of active RNS limbs; every level
//! has its own `RnsContext` (prefix of the prime chain) and its own
//! relinearization key rows, because a fresh encryption at level ℓ is only
//! valid modulo q_ℓ = Π_{i<ℓ} q_i.

use super::encoding::Plaintext;
use super::params::BgvParams;
use crate::math::poly::{RnsContext, RnsPoly};
use crate::math::rng::GlyphRng;
use std::sync::Arc;

/// Shared per-scheme precomputation: one RNS context per level.
pub struct BgvContext {
    pub params: BgvParams,
    /// ctxs[ℓ−1] serves level ℓ (primes[0..ℓ]).
    pub ctxs: Vec<Arc<RnsContext>>,
}

impl BgvContext {
    pub fn new(params: BgvParams) -> Arc<Self> {
        let full = params.context(); // validates alignment
        let mut ctxs = Vec::with_capacity(params.levels());
        for l in 1..=params.levels() {
            if l == params.levels() {
                ctxs.push(full.clone());
            } else {
                ctxs.push(RnsContext::new(params.n, &params.primes[..l]));
            }
        }
        Arc::new(BgvContext { params, ctxs })
    }

    pub fn top_level(&self) -> usize {
        self.params.levels()
    }

    pub fn ctx_at(&self, level: usize) -> &Arc<RnsContext> {
        &self.ctxs[level - 1]
    }

    /// Δ_ℓ = (q_ℓ − 1)/t as RNS residues at level ℓ (the exact LSB→MSB map).
    pub fn delta_rns(&self, level: usize) -> Vec<u64> {
        self.ctx_at(level).delta_rns(self.params.t)
    }
}

/// The ternary secret key.
pub struct BgvSecretKey {
    pub s_coeffs: Vec<i64>,
    /// s in NTT form at top level (truncate for lower levels — the secret's
    /// signed coefficients are level-independent).
    s_ntt: RnsPoly,
    pub ctx: Arc<BgvContext>,
}

impl BgvSecretKey {
    pub fn generate(ctx: &Arc<BgvContext>, rng: &mut GlyphRng) -> Self {
        let s_coeffs: Vec<i64> = (0..ctx.params.n).map(|_| rng.ternary()).collect();
        Self::from_coeffs(ctx, s_coeffs)
    }

    pub fn from_coeffs(ctx: &Arc<BgvContext>, s_coeffs: Vec<i64>) -> Self {
        let top = ctx.top_level();
        let mut s_ntt = RnsPoly::from_signed(ctx.ctx_at(top), &s_coeffs, top);
        s_ntt.to_ntt();
        BgvSecretKey { s_coeffs, s_ntt, ctx: ctx.clone() }
    }

    /// [`Self::from_coeffs`] with the structural invariants checked first —
    /// the entry point for coefficients from an untrusted source (the wire
    /// layer's `ClientKeys` decode): exactly `n` coefficients, all ternary.
    pub fn try_from_coeffs(ctx: &Arc<BgvContext>, s_coeffs: Vec<i64>) -> Result<Self, String> {
        if s_coeffs.len() != ctx.params.n {
            return Err(format!(
                "secret key has {} coefficients, ring degree is {}",
                s_coeffs.len(),
                ctx.params.n
            ));
        }
        if let Some(&bad) = s_coeffs.iter().find(|&&c| !(-1..=1).contains(&c)) {
            return Err(format!("secret-key coefficient {bad} is not ternary"));
        }
        Ok(Self::from_coeffs(ctx, s_coeffs))
    }

    /// s in NTT form truncated to `level` limbs.
    pub fn s_ntt_at(&self, level: usize) -> RnsPoly {
        let mut s = self.s_ntt.clone();
        s.truncate_level(level);
        s
    }

    /// The secret's coefficients as i32 (for LWE extraction in the switch).
    pub fn coeffs_i32(&self) -> Vec<i32> {
        self.s_coeffs.iter().map(|&c| c as i32).collect()
    }

    /// Symmetric encryption at `level` (NTT form): c1 uniform,
    /// c0 = −c1·s + t·e + m, so that phase = c0 + c1·s = m + t·e.
    pub fn encrypt_at(&self, pt: &Plaintext, level: usize, rng: &mut GlyphRng) -> super::BgvCiphertext {
        let rctx = self.ctx.ctx_at(level);
        let t = self.ctx.params.t;
        let sigma = self.ctx.params.sigma;
        let n = self.ctx.params.n;
        let mut c1 = RnsPoly::uniform(rctx, rng, level);
        c1.is_ntt = true; // uniform is uniform in either representation
        let mut c0 = c1.clone();
        c0.mul_assign_ntt(&self.s_ntt_at(level));
        c0.neg_assign();
        // m + t·e in coefficient space, then NTT.
        let mte: Vec<i64> = (0..n)
            .map(|i| pt.coeffs[i] + t as i64 * rng.gaussian_i64(sigma))
            .collect();
        let mut mte = RnsPoly::from_signed(rctx, &mte, level);
        mte.to_ntt();
        c0.add_assign(&mte);
        super::BgvCiphertext { c0, c1, level }
    }

    /// Encrypt at top level.
    pub fn encrypt(&self, pt: &Plaintext, rng: &mut GlyphRng) -> super::BgvCiphertext {
        self.encrypt_at(pt, self.ctx.top_level(), rng)
    }

    /// Decrypt: phase = c0 + c1·s, CRT → centered → mod t.
    pub fn decrypt(&self, ct: &super::BgvCiphertext) -> Plaintext {
        let t = self.ctx.params.t;
        let rctx = self.ctx.ctx_at(ct.level);
        let mut phase = ct.c1.clone();
        debug_assert!(phase.is_ntt, "ciphertexts are kept in NTT form");
        phase.mul_assign_ntt(&self.s_ntt_at(ct.level));
        phase.add_assign(&ct.c0);
        phase.to_coeff();
        let n = self.ctx.params.n;
        let coeffs: Vec<i64> = (0..n)
            .map(|j| {
                let res: Vec<u64> = (0..ct.level).map(|i| phase.res[i][j]).collect();
                Plaintext::center(rctx.crt_coeff_mod_t(&res, t), t)
            })
            .collect();
        Plaintext { coeffs, t }
    }

    /// Remaining noise budget in bits: `log2(q_ℓ/2) − log2(max |t·e|)`.
    /// The decryption margin the noise-budget regression test guards —
    /// lazy relinearization must not silently eat it. Same small-parameter
    /// restriction as [`Self::noise_magnitude`].
    pub fn noise_margin_bits(&self, ct: &super::BgvCiphertext) -> f64 {
        let noise = self.noise_magnitude(ct).max(1) as f64;
        let rctx = self.ctx.ctx_at(ct.level);
        let q_bits: f64 = rctx.primes[..ct.level].iter().map(|&p| (p as f64).log2()).sum();
        (q_bits - 1.0) - noise.log2()
    }

    /// Max |t·e| over coefficients (diagnostics; requires q_ℓ < 2^127, i.e.
    /// ≤ 3 limbs of 32-bit primes).
    pub fn noise_magnitude(&self, ct: &super::BgvCiphertext) -> i128 {
        let rctx = self.ctx.ctx_at(ct.level);
        let t = self.ctx.params.t;
        let mut phase = ct.c1.clone();
        phase.mul_assign_ntt(&self.s_ntt_at(ct.level));
        phase.add_assign(&ct.c0);
        phase.to_coeff();
        let n = self.ctx.params.n;
        let mut worst: i128 = 0;
        for j in 0..n {
            let res: Vec<u64> = (0..ct.level).map(|i| phase.res[i][j]).collect();
            let centered = rctx.crt_coeff_centered_i128(&res);
            let m = Plaintext::center(rctx.crt_coeff_mod_t(&res, t), t) as i128;
            worst = worst.max((centered - m).abs());
        }
        worst
    }
}

/// Relinearization key: per level, RNS-decomposition key switching rows for
/// s² → s. Row i at level ℓ encrypts `B_i·s²` where
/// `B_i = (q_ℓ/q_i)·[(q_ℓ/q_i)^{−1}]_{q_i}` (so `Σ_i [c]_{q_i}·B_i ≡ c`).
pub struct RelinKey {
    /// rows[ℓ−1][i] = (k0, k1) in NTT form at level ℓ.
    pub rows: Vec<Vec<(RnsPoly, RnsPoly)>>,
}

impl RelinKey {
    pub fn generate(sk: &BgvSecretKey, rng: &mut GlyphRng) -> Self {
        let ctx = &sk.ctx;
        let t = ctx.params.t;
        let sigma = ctx.params.sigma;
        let n = ctx.params.n;
        let mut rows = Vec::with_capacity(ctx.top_level());
        for level in 1..=ctx.top_level() {
            let rctx = ctx.ctx_at(level);
            let s_ntt = sk.s_ntt_at(level);
            // s² in NTT form.
            let mut s2 = s_ntt.clone();
            s2.mul_assign_ntt(&s_ntt);
            let mut level_rows = Vec::with_capacity(level);
            for i in 0..level {
                // B_i as residues at this level.
                let b_i = rctx.scalar_to_rns_big(&{
                    let mut prod = crate::math::poly::BigUintSmall::from_u64(1);
                    for (j, &pj) in rctx.primes.iter().enumerate() {
                        if j != i {
                            prod = prod.mul_u64(pj);
                        }
                    }
                    let inv = crate::math::modarith::inv_mod(prod.rem_u64(rctx.primes[i]), rctx.primes[i]);
                    prod.mul_u64(inv)
                });
                // k1 uniform; k0 = −k1·s + t·e + B_i·s².
                let mut k1 = RnsPoly::uniform(rctx, rng, level);
                k1.is_ntt = true;
                let mut k0 = k1.clone();
                k0.mul_assign_ntt(&s_ntt);
                k0.neg_assign();
                let te: Vec<i64> = (0..n).map(|_| t as i64 * rng.gaussian_i64(sigma)).collect();
                let mut te = RnsPoly::from_signed(rctx, &te, level);
                te.to_ntt();
                k0.add_assign(&te);
                let mut bs2 = s2.clone();
                bs2.scalar_mul_assign(&b_i);
                k0.add_assign(&bs2);
                level_rows.push((k0, k1));
            }
            rows.push(level_rows);
        }
        RelinKey { rows }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Arc<BgvContext>, BgvSecretKey, GlyphRng) {
        let ctx = BgvContext::new(BgvParams::test_params());
        let mut rng = GlyphRng::new(100);
        let sk = BgvSecretKey::generate(&ctx, &mut rng);
        (ctx, sk, rng)
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let (ctx, sk, mut rng) = setup();
        let vals: Vec<i64> = vec![0, 1, -1, 300, -300, 32767, -32767];
        let pt = Plaintext::encode_batch(&vals, &ctx.params);
        let ct = sk.encrypt(&pt, &mut rng);
        assert_eq!(sk.decrypt(&ct).decode_batch(vals.len()), vals);
    }

    #[test]
    fn encrypt_at_lower_levels_roundtrips() {
        let (ctx, sk, mut rng) = setup();
        let vals = vec![7i64, -9, 127];
        let pt = Plaintext::encode_batch(&vals, &ctx.params);
        for level in 1..=ctx.top_level() {
            let ct = sk.encrypt_at(&pt, level, &mut rng);
            assert_eq!(sk.decrypt(&ct).decode_batch(3), vals, "level {level}");
        }
    }

    #[test]
    fn fresh_noise_is_small() {
        let (ctx, sk, mut rng) = setup();
        let pt = Plaintext::encode_batch(&[5], &ctx.params);
        let ct = sk.encrypt(&pt, &mut rng);
        let noise = sk.noise_magnitude(&ct);
        // fresh noise ≈ t·(σ + convolution) — far below q/2
        assert!(noise < (ctx.params.t as i128) << 20, "noise={noise}");
        assert!(noise > 0);
        // margin view of the same fact: ~96-bit q vs ~2^20·t noise
        let margin = sk.noise_margin_bits(&ct);
        assert!(margin > 40.0, "margin={margin}");
        assert!(margin < 96.0, "margin={margin}");
    }

    #[test]
    fn delta_map_is_noise_free() {
        // ×Δ sends phase m + t·e to Δ·m − e: noise must not grow.
        let (ctx, sk, mut rng) = setup();
        let pt = Plaintext::encode_batch(&[123, -77], &ctx.params);
        let mut ct = sk.encrypt(&pt, &mut rng);
        let before = sk.noise_magnitude(&ct);
        let delta = ctx.delta_rns(ct.level);
        ct.c0.scalar_mul_assign(&delta);
        ct.c1.scalar_mul_assign(&delta);
        // phase is now Δ·m − e (MSB encoding): decrypting mod t is no longer
        // meaningful, but the *magnitude* of the deviation from Δ·m must be
        // ≈ e = before/t.
        let rctx = ctx.ctx_at(ct.level);
        let mut phase = ct.c1.clone();
        phase.mul_assign_ntt(&sk.s_ntt_at(ct.level));
        phase.add_assign(&ct.c0);
        phase.to_coeff();
        // reconstruct Δ as bigint low bits? Instead check coefficient 2 which
        // encodes 0: phase must be ≈ 0 (|−e| small).
        let res: Vec<u64> = (0..ct.level).map(|i| phase.res[i][2]).collect();
        let dev = rctx.crt_coeff_centered_i128(&res).abs();
        assert!(dev <= before / ctx.params.t as i128 + 4, "dev={dev} before={before}");
    }
}
