//! Bit-sliced homomorphic table lookup (TLU) — the FHESGD baseline's
//! activation mechanism (paper §2.5, Table 1 "TLU" row).
//!
//! The lookup runs in the t = 2 profile on *single-lane* bit ciphertexts
//! (value at coefficient 0 — a constant polynomial): the indicator tree
//! multiplies two ciphertexts whose product must be lane-wise, and
//! batch-in-coefficients packing only supports ct×ct when one operand is a
//! constant polynomial (DESIGN.md §2.1). FHESGD packed the batch in HElib
//! slots and amortized one lookup over 60 samples; our lookup processes one
//! sample per op, and the substitution (and its effect on absolute, not
//! relative, latencies) is documented in DESIGN.md §5.
//!
//! A binary indicator tree computes all 2^b window indicators with
//! 2·(2^b − 1) MultCC at depth b (mod-switching after every tree level);
//! each output bit is the XOR (= AddCC mod 2) of the indicators whose table
//! entry has that bit set. This is the Crawford-et-al-style lookup FHESGD
//! builds sigmoid from, and it is why the baseline's activations are orders
//! of magnitude more expensive than a MAC — the imbalance Glyph removes.

use super::ciphertext::BgvCiphertext;
use super::keys::{BgvContext, RelinKey};
use crate::bgv::encoding::Plaintext;

/// Operation counts of one lookup (for the paper's HOP tables).
#[derive(Clone, Copy, Debug, Default)]
pub struct LutCost {
    pub mult_cc: usize,
    pub add_cc: usize,
    pub mod_switches: usize,
}

/// A lookup table mapping b-bit inputs to `out_bits`-bit outputs.
pub struct LookupTable {
    pub in_bits: usize,
    pub out_bits: usize,
    /// entries[v] = output word for input v (v is MSB-first bit order below).
    pub entries: Vec<u64>,
}

impl LookupTable {
    pub fn new(in_bits: usize, out_bits: usize, f: impl Fn(u64) -> u64) -> Self {
        let entries = (0..(1u64 << in_bits)).map(f).collect();
        LookupTable { in_bits, out_bits, entries }
    }

    /// Quantized sigmoid over signed fixed-point inputs, the FHESGD
    /// activation: input v interpreted as signed b-bit scaled by 2^frac,
    /// output an unsigned b-bit value of sigmoid(x) scaled by 2^out_frac.
    pub fn sigmoid(in_bits: usize, frac: u32, out_frac: u32) -> Self {
        Self::new(in_bits, in_bits, move |v| {
            let half = 1i64 << (in_bits - 1);
            let sv = if (v as i64) >= half { v as i64 - (1i64 << in_bits) } else { v as i64 };
            let x = sv as f64 / 2f64.powi(frac as i32);
            let s = 1.0 / (1.0 + (-x).exp());
            let q = (s * 2f64.powi(out_frac as i32)).round() as u64;
            q.min((1 << in_bits) - 1)
        })
    }

    /// Homomorphic evaluation. `bits` are MSB-first *single-lane* bit
    /// ciphertexts of the input (t = 2 profile, value at coefficient 0).
    /// Returns MSB-first output bit ciphertexts and the operation counts.
    pub fn evaluate(
        &self,
        bits: &[BgvCiphertext],
        rlk: &RelinKey,
        ctx: &BgvContext,
    ) -> (Vec<BgvCiphertext>, LutCost) {
        assert_eq!(bits.len(), self.in_bits);
        assert_eq!(ctx.params.t, 2, "TLU runs in the t = 2 profile");
        assert!(
            ctx.top_level() > self.in_bits,
            "need > in_bits levels (one MultCC + mod-switch per tree stage)"
        );
        let mut cost = LutCost::default();
        let one = Plaintext::encode_scalar(1, &ctx.params);

        // Indicator tree, MSB first: after stage k there are 2^(k+1)
        // indicators, inds[p] = ∏ match(bit_i, p_i).
        let mut inds: Vec<BgvCiphertext> = vec![BgvCiphertext::trivial(&one, ctx, ctx.top_level())];
        let mut level = ctx.top_level();
        for bit in bits {
            let mut b = bit.clone();
            b.mod_switch_to(level, ctx);
            cost.mod_switches += bit.level - level;
            // not_b = 1 + b (mod 2)
            let mut not_b = b.clone();
            not_b.add_plain(&one, ctx);
            let mut next = Vec::with_capacity(inds.len() * 2);
            for ind in &inds {
                // ind ∧ ¬b, ind ∧ b
                let mut i0 = ind.clone();
                i0.mul_assign(&not_b, rlk, ctx);
                i0.mod_switch_down(ctx);
                let mut i1 = ind.clone();
                i1.mul_assign(&b, rlk, ctx);
                i1.mod_switch_down(ctx);
                cost.mult_cc += 2;
                cost.mod_switches += 2;
                next.push(i0);
                next.push(i1);
            }
            inds = next;
            level -= 1;
        }

        // Output bit j (MSB-first) = Σ_v entries[v]>>j & 1 · inds[v]  (mod 2).
        let zero = Plaintext::encode_scalar(0, &ctx.params);
        let mut out = Vec::with_capacity(self.out_bits);
        for j in (0..self.out_bits).rev() {
            let mut acc = BgvCiphertext::trivial(&zero, ctx, level);
            for (v, ind) in inds.iter().enumerate() {
                if (self.entries[v] >> j) & 1 == 1 {
                    acc.add_assign(ind);
                    cost.add_cc += 1;
                }
            }
            out.push(acc);
        }
        (out, cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bgv::keys::BgvSecretKey;
    use crate::bgv::params::BgvParams;
    use crate::math::rng::GlyphRng;
    use std::sync::Arc;

    struct Fx {
        ctx: Arc<BgvContext>,
        sk: BgvSecretKey,
        rlk: RelinKey,
        rng: GlyphRng,
    }

    fn fixture() -> Fx {
        let ctx = BgvContext::new(BgvParams::test_tlu_params());
        let mut rng = GlyphRng::new(200);
        let sk = BgvSecretKey::generate(&ctx, &mut rng);
        let rlk = RelinKey::generate(&sk, &mut rng);
        Fx { ctx, sk, rlk, rng }
    }

    /// Encrypt the bits (MSB-first) of one value, single-lane.
    fn encrypt_bits(f: &mut Fx, value: u64, bits: usize) -> Vec<BgvCiphertext> {
        (0..bits)
            .rev()
            .map(|j| {
                let pt = Plaintext::encode_scalar(((value >> j) & 1) as i64, &f.ctx.params);
                f.sk.encrypt(&pt, &mut f.rng)
            })
            .collect()
    }

    fn decrypt_value(f: &Fx, bits: &[BgvCiphertext]) -> u64 {
        let mut val = 0u64;
        for ct in bits {
            let lane = f.sk.decrypt(ct);
            val = (val << 1) | (lane.coeffs[0].rem_euclid(2)) as u64;
        }
        val
    }

    #[test]
    fn lookup_4bit_square_table() {
        let mut f = fixture();
        let table = LookupTable::new(4, 4, |v| (v * v) & 0xF);
        for input in [0u64, 3, 7, 12, 15] {
            let bits = encrypt_bits(&mut f, input, 4);
            let (out, cost) = table.evaluate(&bits, &f.rlk, &f.ctx);
            assert_eq!(decrypt_value(&f, &out), (input * input) & 0xF, "input={input}");
            assert_eq!(cost.mult_cc, 2 * ((1 << 4) - 1)); // 30
        }
    }

    #[test]
    fn sigmoid_table_shape() {
        let t = LookupTable::sigmoid(6, 2, 5);
        // sigmoid(0) = 0.5 → 16 at out_frac=5
        assert_eq!(t.entries[0], 16);
        // large positive input → ~32 (saturating), large negative → ~0
        assert!(t.entries[15] >= 30); // v=15 → x=3.75
        assert!(t.entries[32] <= 2); // v=32 → x=-8
        // monotone on the positive half
        assert!(t.entries[1] <= t.entries[8]);
    }

    #[test]
    fn homomorphic_sigmoid_matches_plain_table() {
        let mut f = fixture();
        let table = LookupTable::sigmoid(4, 1, 3);
        for input in [0u64, 1, 5, 8, 12, 15] {
            let bits = encrypt_bits(&mut f, input, 4);
            let (out, _) = table.evaluate(&bits, &f.rlk, &f.ctx);
            assert_eq!(decrypt_value(&f, &out), table.entries[input as usize], "input={input}");
        }
    }
}
