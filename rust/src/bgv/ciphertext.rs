//! BGV ciphertext operations: the paper's Table-1 op set.
//!
//! * `AddCC` / `SubCC` — coefficient-wise (cheap);
//! * `MultCP` — ciphertext × plaintext (transfer-learning convolutions);
//! * `MultCC` — ciphertext × ciphertext with RNS relinearization (the
//!   encrypted-weight FC/conv MACs);
//! * modulus switching — noise management between levels;
//! * the Δ scalar maps used by the cryptosystem switch.
//!
//! Ciphertexts are kept in NTT form; modulus switching round-trips through
//! coefficient form internally.

use super::encoding::Plaintext;
use super::keys::{BgvContext, RelinKey};
use crate::math::poly::RnsPoly;

/// A degree-1 BGV ciphertext `(c0, c1)` with phase `c0 + c1·s = m + t·e`.
#[derive(Clone)]
pub struct BgvCiphertext {
    pub c0: RnsPoly,
    pub c1: RnsPoly,
    pub level: usize,
}

impl BgvCiphertext {
    /// Noise-free encryption of a plaintext (server-side constants).
    pub fn trivial(pt: &Plaintext, ctx: &BgvContext, level: usize) -> Self {
        let rctx = ctx.ctx_at(level);
        let mut c0 = pt.to_rns(rctx, level);
        c0.to_ntt();
        let mut c1 = RnsPoly::zero(rctx, level);
        c1.is_ntt = true;
        BgvCiphertext { c0, c1, level }
    }

    /// AddCC.
    pub fn add_assign(&mut self, o: &Self) {
        debug_assert_eq!(self.level, o.level, "level mismatch — mod-switch first");
        self.c0.add_assign(&o.c0);
        self.c1.add_assign(&o.c1);
    }

    /// SubCC.
    pub fn sub_assign(&mut self, o: &Self) {
        debug_assert_eq!(self.level, o.level);
        self.c0.sub_assign(&o.c0);
        self.c1.sub_assign(&o.c1);
    }

    pub fn neg_assign(&mut self) {
        self.c0.neg_assign();
        self.c1.neg_assign();
    }

    /// Add a plaintext (AddCP).
    pub fn add_plain(&mut self, pt: &Plaintext, ctx: &BgvContext) {
        let rctx = ctx.ctx_at(self.level);
        let mut p = pt.to_rns(rctx, self.level);
        p.to_ntt();
        self.c0.add_assign(&p);
    }

    /// MultCP: multiply by a plaintext polynomial.
    pub fn mul_plain_assign(&mut self, pt: &Plaintext, ctx: &BgvContext) {
        let rctx = ctx.ctx_at(self.level);
        let mut p = pt.to_rns(rctx, self.level);
        p.to_ntt();
        self.c0.mul_assign_ntt(&p);
        self.c1.mul_assign_ntt(&p);
    }

    /// Multiply by a small integer scalar (noise ×|k|, no key material).
    pub fn small_scalar_mul_assign(&mut self, k: i64, ctx: &BgvContext) {
        let rctx = ctx.ctx_at(self.level);
        let res = rctx.scalar_to_rns_i64(k);
        self.c0.scalar_mul_assign(&res);
        self.c1.scalar_mul_assign(&res);
    }

    /// Multiply both components by an RNS scalar (the Δ maps of the switch).
    pub fn rns_scalar_mul_assign(&mut self, scalar_rns: &[u64]) {
        self.c0.scalar_mul_assign(scalar_rns);
        self.c1.scalar_mul_assign(scalar_rns);
    }

    /// MultCC with relinearization: `self ← self ⊗ o`.
    pub fn mul_assign(&mut self, o: &Self, rlk: &RelinKey, ctx: &BgvContext) {
        debug_assert_eq!(self.level, o.level);
        debug_assert!(self.c0.is_ntt && o.c0.is_ntt);
        let level = self.level;
        // Tensor: (d0, d1, d2) = (c0·o0, c0·o1 + c1·o0, c1·o1)
        let mut d0 = self.c0.clone();
        d0.mul_assign_ntt(&o.c0);
        let mut d1a = self.c0.clone();
        d1a.mul_assign_ntt(&o.c1);
        let mut d1b = self.c1.clone();
        d1b.mul_assign_ntt(&o.c0);
        d1a.add_assign(&d1b);
        let mut d2 = self.c1.clone();
        d2.mul_assign_ntt(&o.c1);

        // Relinearize d2: RNS-decompose in coefficient space.
        d2.to_coeff();
        let rctx = ctx.ctx_at(level);
        let n = rctx.n;
        for i in 0..level {
            // digit polynomial = centered [d2]_{q_i}, lifted to all limbs.
            let qi = rctx.primes[i];
            let digits: Vec<i64> = (0..n)
                .map(|j| {
                    let v = d2.res[i][j];
                    if v > qi / 2 {
                        v as i64 - qi as i64
                    } else {
                        v as i64
                    }
                })
                .collect();
            let mut dig = RnsPoly::from_signed(rctx, &digits, level);
            dig.to_ntt();
            let (k0, k1) = &rlk.rows[level - 1][i];
            d0.mul_acc_ntt(&dig, k0);
            d1a.mul_acc_ntt(&dig, k1);
        }
        self.c0 = d0;
        self.c1 = d1a;
    }

    /// Modulus switch down one level (both components).
    pub fn mod_switch_down(&mut self, ctx: &BgvContext) {
        let t = ctx.params.t;
        self.c0.to_coeff();
        self.c1.to_coeff();
        self.c0.mod_switch_down(t);
        self.c1.mod_switch_down(t);
        self.level -= 1;
        // Re-bind the polynomials' context to the shrunken level's tables is
        // unnecessary: limb i tables are identical across contexts.
        self.c0.to_ntt();
        self.c1.to_ntt();
    }

    /// Mod-switch until at `target` level.
    pub fn mod_switch_to(&mut self, target: usize, ctx: &BgvContext) {
        while self.level > target {
            self.mod_switch_down(ctx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bgv::keys::BgvSecretKey;
    use crate::bgv::params::BgvParams;
    use crate::math::rng::GlyphRng;
    use std::sync::Arc;

    struct Fx {
        ctx: Arc<BgvContext>,
        sk: BgvSecretKey,
        rlk: RelinKey,
        rng: GlyphRng,
    }

    fn fixture(seed: u64) -> Fx {
        let ctx = BgvContext::new(BgvParams::test_params());
        let mut rng = GlyphRng::new(seed);
        let sk = BgvSecretKey::generate(&ctx, &mut rng);
        let rlk = RelinKey::generate(&sk, &mut rng);
        Fx { ctx, sk, rlk, rng }
    }

    fn enc(f: &mut Fx, vals: &[i64]) -> BgvCiphertext {
        let pt = Plaintext::encode_batch(vals, &f.ctx.params);
        f.sk.encrypt(&pt, &mut f.rng)
    }

    fn dec(f: &Fx, ct: &BgvCiphertext, k: usize) -> Vec<i64> {
        f.sk.decrypt(ct).decode_batch(k)
    }

    #[test]
    fn add_sub_cc() {
        let mut f = fixture(1);
        let a = enc(&mut f, &[10, -20, 30]);
        let b = enc(&mut f, &[1, 2, -3]);
        let mut c = a.clone();
        c.add_assign(&b);
        assert_eq!(dec(&f, &c, 3), vec![11, -18, 27]);
        c.sub_assign(&b);
        assert_eq!(dec(&f, &c, 3), vec![10, -20, 30]);
    }

    #[test]
    fn mult_cp_batchwise_scalar() {
        let mut f = fixture(2);
        let mut x = enc(&mut f, &[5, -7, 11, 0]);
        let w = Plaintext::encode_scalar(-6, &f.ctx.params);
        x.mul_plain_assign(&w, &f.ctx);
        assert_eq!(dec(&f, &x, 4), vec![-30, 42, -66, 0]);
    }

    #[test]
    fn mult_cc_constant_weight_times_batch() {
        // The Glyph MAC shape: weight ct (constant poly) × value ct (batch
        // in coefficients) = batch-wise scalar product.
        let mut f = fixture(3);
        let mut w = enc(&mut f, &[9]); // constant poly: only coeff 0
        let x = enc(&mut f, &[3, -4, 120, -128]);
        w.mul_assign(&x, &f.rlk, &f.ctx);
        assert_eq!(dec(&f, &w, 4), vec![27, -36, 1080, -1152]);
    }

    #[test]
    fn mac_accumulation_matches_plain() {
        // Σ_i w_i ⊗ x_i over 16 terms — one FC neuron on a batch of 4.
        let mut f = fixture(4);
        let mut rng2 = GlyphRng::new(777);
        let mut acc: Option<BgvCiphertext> = None;
        let mut want = vec![0i64; 4];
        for _ in 0..16 {
            let wv = (rng2.uniform_mod(255) as i64) - 127;
            let xs: Vec<i64> = (0..4).map(|_| (rng2.uniform_mod(255) as i64) - 127).collect();
            for b in 0..4 {
                want[b] += wv * xs[b];
            }
            let mut wct = enc(&mut f, &[wv]);
            let xct = enc(&mut f, &xs);
            wct.mul_assign(&xct, &f.rlk, &f.ctx);
            match &mut acc {
                None => acc = Some(wct),
                Some(a) => a.add_assign(&wct),
            }
        }
        assert_eq!(dec(&f, &acc.unwrap(), 4), want);
    }

    #[test]
    fn mod_switch_preserves_plaintext() {
        let mut f = fixture(5);
        let vals = vec![1234i64, -4321, 77];
        let mut ct = enc(&mut f, &vals);
        ct.mod_switch_down(&f.ctx);
        assert_eq!(ct.level, f.ctx.top_level() - 1);
        assert_eq!(dec(&f, &ct, 3), vals);
    }

    #[test]
    fn mod_switch_shrinks_post_mult_noise() {
        // After a MultCC the noise is large; dropping a limb divides it by
        // ~q_last (plus a small t-sized rounding term).
        let mut f = fixture(55);
        let mut a = enc(&mut f, &[99, -2]);
        let w = enc(&mut f, &[3]); // constant poly
        a.mul_assign(&w, &f.rlk, &f.ctx);
        let noise_before = f.sk.noise_magnitude(&a);
        a.mod_switch_down(&f.ctx);
        let noise_after = f.sk.noise_magnitude(&a);
        assert_eq!(dec(&f, &a, 2), vec![297, -6]);
        assert!(noise_after < noise_before / 1000, "{noise_after} !< {noise_before}/1000");
    }

    #[test]
    fn depth_two_with_mod_switch() {
        // Batch ct × scalar weight × scalar weight (batch-wise semantics
        // require constant-poly multiplicands — DESIGN.md §2.1).
        let mut f = fixture(6);
        let mut a = enc(&mut f, &[12, -5]);
        let b = enc(&mut f, &[-3]);
        a.mul_assign(&b, &f.rlk, &f.ctx); // depth 1
        a.mod_switch_down(&f.ctx);
        let mut c = enc(&mut f, &[2]);
        c.mod_switch_to(a.level, &f.ctx);
        a.mul_assign(&c, &f.rlk, &f.ctx); // depth 2
        assert_eq!(dec(&f, &a, 2), vec![12 * -3 * 2, -5 * -3 * 2]);
    }

    #[test]
    fn batch_times_batch_is_negacyclic_convolution() {
        // Documents the §2.1 constraint: two batch-packed operands convolve.
        let mut f = fixture(66);
        let mut a = enc(&mut f, &[2, 3]);
        let b = enc(&mut f, &[5, 7]);
        a.mul_assign(&b, &f.rlk, &f.ctx);
        // (2 + 3X)(5 + 7X) = 10 + 29X + 21X²
        let got = dec(&f, &a, 3);
        assert_eq!(got, vec![10, 29, 21]);
    }

    #[test]
    fn trivial_ciphertext_ops() {
        let mut f = fixture(7);
        let pt = Plaintext::encode_batch(&[100, -100], &f.ctx.params);
        let triv = BgvCiphertext::trivial(&pt, &f.ctx, f.ctx.top_level());
        assert_eq!(dec(&f, &triv, 2), vec![100, -100]);
        let mut x = enc(&mut f, &[1, 1]);
        x.add_assign(&triv);
        assert_eq!(dec(&f, &x, 2), vec![101, -99]);
    }

    #[test]
    fn add_plain_and_small_scalar() {
        let mut f = fixture(8);
        let mut x = enc(&mut f, &[10, 20]);
        let pt = Plaintext::encode_batch(&[-3, 4], &f.ctx.params);
        x.add_plain(&pt, &f.ctx);
        assert_eq!(dec(&f, &x, 2), vec![7, 24]);
        x.small_scalar_mul_assign(-2, &f.ctx);
        assert_eq!(dec(&f, &x, 2), vec![-14, -48]);
    }

    #[test]
    fn negation() {
        let mut f = fixture(9);
        let mut x = enc(&mut f, &[42, -17]);
        x.neg_assign();
        assert_eq!(dec(&f, &x, 2), vec![-42, 17]);
    }

    #[test]
    fn noise_after_multcc_within_budget() {
        let mut f = fixture(10);
        let mut a = enc(&mut f, &[127]);
        let b = enc(&mut f, &[-127]);
        a.mul_assign(&b, &f.rlk, &f.ctx);
        let noise = f.sk.noise_magnitude(&a);
        // must be far below q/2 ≈ 2^95
        assert!(noise < 1i128 << 80, "noise 2^{:.1}", (noise as f64).log2());
        assert_eq!(dec(&f, &a, 1), vec![-16129]);
    }
}
