//! BGV ciphertext operations: the paper's Table-1 op set.
//!
//! * `AddCC` / `SubCC` — coefficient-wise (cheap);
//! * `MultCP` — ciphertext × plaintext (transfer-learning convolutions);
//! * `MultCC` — ciphertext × ciphertext with RNS relinearization (the
//!   encrypted-weight FC/conv MACs);
//! * modulus switching — noise management between levels;
//! * the Δ scalar maps used by the cryptosystem switch.
//!
//! Ciphertexts are kept in NTT form; modulus switching round-trips through
//! coefficient form internally.
//!
//! The per-term `mul_assign`/`mul_plain_assign` ops are retained as the
//! reference oracle; the hot path is the scratch-backed MAC engine below
//! ([`BgvScratch`] + [`mac_row`]): a whole `Σ_i w_i ⊗ x_i` row accumulates
//! the raw tensor components `(d0, d1, d2)` in NTT form and relinearizes
//! **once** at [`BgvScratch::relin_finalize`] instead of once per term —
//! ~`in_dim`× fewer relinearizations per FC row. Every per-term accumulate
//! and the `relin_finalize_into` finalizer are allocation-free (asserted
//! by `tests/zero_alloc_bgv.rs`); the engine's [`mac_row`] additionally
//! allocates the one *returned* output ciphertext per row, amortized over
//! the row's terms. Equivalence against the reference path is locked by
//! `tests/bgv_mac_equivalence.rs`.

use super::encoding::{CachedPlaintext, Plaintext};
use super::keys::{BgvContext, RelinKey};
use crate::math::modarith::barrett_reduce;
use crate::math::poly::{RnsContext, RnsPoly};
use std::sync::Arc;

/// A degree-1 BGV ciphertext `(c0, c1)` with phase `c0 + c1·s = m + t·e`.
#[derive(Clone)]
pub struct BgvCiphertext {
    pub c0: RnsPoly,
    pub c1: RnsPoly,
    pub level: usize,
}

impl BgvCiphertext {
    /// Noise-free encryption of a plaintext (server-side constants).
    pub fn trivial(pt: &Plaintext, ctx: &BgvContext, level: usize) -> Self {
        let rctx = ctx.ctx_at(level);
        let mut c0 = pt.to_rns(rctx, level);
        c0.to_ntt();
        let mut c1 = RnsPoly::zero(rctx, level);
        c1.is_ntt = true;
        BgvCiphertext { c0, c1, level }
    }

    /// AddCC.
    pub fn add_assign(&mut self, o: &Self) {
        debug_assert_eq!(self.level, o.level, "level mismatch — mod-switch first");
        self.c0.add_assign(&o.c0);
        self.c1.add_assign(&o.c1);
    }

    /// SubCC.
    pub fn sub_assign(&mut self, o: &Self) {
        debug_assert_eq!(self.level, o.level);
        self.c0.sub_assign(&o.c0);
        self.c1.sub_assign(&o.c1);
    }

    pub fn neg_assign(&mut self) {
        self.c0.neg_assign();
        self.c1.neg_assign();
    }

    /// Add a plaintext (AddCP).
    pub fn add_plain(&mut self, pt: &Plaintext, ctx: &BgvContext) {
        let rctx = ctx.ctx_at(self.level);
        let mut p = pt.to_rns(rctx, self.level);
        p.to_ntt();
        self.c0.add_assign(&p);
    }

    /// MultCP: multiply by a plaintext polynomial (reference path — redoes
    /// the RNS lift + forward NTT per call; hot paths use the cached form).
    pub fn mul_plain_assign(&mut self, pt: &Plaintext, ctx: &BgvContext) {
        let rctx = ctx.ctx_at(self.level);
        let mut p = pt.to_rns(rctx, self.level);
        p.to_ntt();
        self.c0.mul_assign_ntt(&p);
        self.c1.mul_assign_ntt(&p);
    }

    /// MultCP against a precomputed evaluation-form weight: a pure
    /// pointwise pass, no per-call `to_rns`/`to_ntt`.
    pub fn mul_plain_cached_assign(&mut self, w: &CachedPlaintext) {
        let p = w.ntt_at(self.level);
        self.c0.mul_assign_ntt(p);
        self.c1.mul_assign_ntt(p);
    }

    /// Multiply by a small integer scalar (noise ×|k|, no key material).
    pub fn small_scalar_mul_assign(&mut self, k: i64, ctx: &BgvContext) {
        let rctx = ctx.ctx_at(self.level);
        let res = rctx.scalar_to_rns_i64(k);
        self.c0.scalar_mul_assign(&res);
        self.c1.scalar_mul_assign(&res);
    }

    /// Multiply both components by an RNS scalar (the Δ maps of the switch).
    pub fn rns_scalar_mul_assign(&mut self, scalar_rns: &[u64]) {
        self.c0.scalar_mul_assign(scalar_rns);
        self.c1.scalar_mul_assign(scalar_rns);
    }

    /// MultCC with relinearization: `self ← self ⊗ o`.
    pub fn mul_assign(&mut self, o: &Self, rlk: &RelinKey, ctx: &BgvContext) {
        debug_assert_eq!(self.level, o.level);
        debug_assert!(self.c0.is_ntt && o.c0.is_ntt);
        let level = self.level;
        // Tensor: (d0, d1, d2) = (c0·o0, c0·o1 + c1·o0, c1·o1)
        let mut d0 = self.c0.clone();
        d0.mul_assign_ntt(&o.c0);
        let mut d1a = self.c0.clone();
        d1a.mul_assign_ntt(&o.c1);
        let mut d1b = self.c1.clone();
        d1b.mul_assign_ntt(&o.c0);
        d1a.add_assign(&d1b);
        let mut d2 = self.c1.clone();
        d2.mul_assign_ntt(&o.c1);

        // Relinearize d2: RNS-decompose in coefficient space.
        d2.to_coeff();
        let rctx = ctx.ctx_at(level);
        let n = rctx.n;
        for i in 0..level {
            // digit polynomial = centered [d2]_{q_i}, lifted to all limbs.
            let qi = rctx.primes[i];
            let digits: Vec<i64> = (0..n)
                .map(|j| {
                    let v = d2.res[i][j];
                    if v > qi / 2 {
                        v as i64 - qi as i64
                    } else {
                        v as i64
                    }
                })
                .collect();
            let mut dig = RnsPoly::from_signed(rctx, &digits, level);
            dig.to_ntt();
            let (k0, k1) = &rlk.rows[level - 1][i];
            d0.mul_acc_ntt(&dig, k0);
            d1a.mul_acc_ntt(&dig, k1);
        }
        self.c0 = d0;
        self.c1 = d1a;
    }

    /// Modulus switch down one level (both components).
    pub fn mod_switch_down(&mut self, ctx: &BgvContext) {
        let t = ctx.params.t;
        self.c0.to_coeff();
        self.c1.to_coeff();
        self.c0.mod_switch_down(t);
        self.c1.mod_switch_down(t);
        self.level -= 1;
        // Re-bind the polynomials' context to the shrunken level's tables is
        // unnecessary: limb i tables are identical across contexts.
        self.c0.to_ntt();
        self.c1.to_ntt();
    }

    /// Mod-switch until at `target` level.
    pub fn mod_switch_to(&mut self, target: usize, ctx: &BgvContext) {
        while self.level > target {
            self.mod_switch_down(ctx);
        }
    }
}

// ---------------------------------------------------------------------------
// The scratch-backed, lazy-relinearization MAC engine (the BGV hot path).
// ---------------------------------------------------------------------------

/// One term of a deferred-relinearization MAC row.
///
/// A *row* is one output neuron's accumulation `Σ_i term_i`; all terms must
/// share one level. `Cc` terms contribute to the degree-2 tensor
/// accumulator (relinearized once at finalize), `Cp` terms are degree-1 and
/// relin-free.
#[derive(Clone, Copy)]
pub enum MacTerm<'a> {
    /// Encrypted weight ⊗ encrypted value (MultCC, lazy relin).
    Cc(&'a BgvCiphertext, &'a BgvCiphertext),
    /// Encrypted value × cached plaintext weight (MultCP).
    Cp(&'a BgvCiphertext, &'a CachedPlaintext),
}

impl MacTerm<'_> {
    /// The level the term's ciphertext operands live at.
    pub fn level(&self) -> usize {
        match self {
            MacTerm::Cc(a, _) => a.level,
            MacTerm::Cp(x, _) => x.level,
        }
    }
}

/// Reusable accumulation state for one worker's MAC rows.
///
/// Holds the NTT-domain tensor accumulators `(d0, d1, d2)` plus the digit
/// polynomial of the relinearization, all sized on first use and reused
/// across rows (`begin` re-zeros in place when the ring/level matches), so
/// a steady-state MAC performs **zero** heap allocations.
pub struct BgvScratch {
    d0: Option<RnsPoly>,
    d1: Option<RnsPoly>,
    d2: Option<RnsPoly>,
    /// Relinearization digit polynomial, reused across rows and limbs.
    dig: Option<RnsPoly>,
    /// Whether any `Cc` term touched `d2` (pure-`Cp` rows skip relin).
    has_d2: bool,
    level: usize,
}

impl BgvScratch {
    pub fn new() -> Self {
        BgvScratch { d0: None, d1: None, d2: None, dig: None, has_d2: false, level: 0 }
    }

    /// Whether a warm buffer can be reused for `(rctx, level)`: same ring
    /// degree and the same prime-chain prefix (NTT tables are per-prime, so
    /// matching primes ⇒ matching tables even across context instances).
    fn fits(p: &Option<RnsPoly>, rctx: &Arc<RnsContext>, level: usize) -> bool {
        match p {
            Some(q) => {
                q.level == level
                    && q.n() == rctx.n
                    && q.ctx.primes[..level] == rctx.primes[..level]
            }
            None => false,
        }
    }

    /// Start a fresh accumulation at `level`. Steady state (same ring and
    /// level as the previous row) re-zeros the warm buffers in place —
    /// except `dig`, which every relinearization fully overwrites before
    /// reading, and `d2` when the previous row never dirtied it (pure-`Cp`
    /// rows — the dominant transfer-learning path — skip both).
    pub fn begin(&mut self, rctx: &Arc<RnsContext>, level: usize) {
        let d2_dirty = self.has_d2;
        for (slot, clear) in [
            (&mut self.d0, true),
            (&mut self.d1, true),
            (&mut self.d2, d2_dirty),
            (&mut self.dig, false),
        ] {
            if Self::fits(slot, rctx, level) {
                let p = slot.as_mut().expect("fits() checked Some");
                if clear {
                    p.clear();
                }
                p.is_ntt = true;
            } else {
                let mut p = RnsPoly::zero(rctx, level);
                p.is_ntt = true;
                *slot = Some(p);
            }
        }
        self.has_d2 = false;
        self.level = level;
    }

    /// MultCC accumulate without relinearization:
    /// `(d0, d1, d2) += (a0·b0, a0·b1 + a1·b0, a1·b1)`.
    pub fn mac_cc_tensor_into(&mut self, a: &BgvCiphertext, b: &BgvCiphertext) {
        debug_assert_eq!(a.level, b.level, "level mismatch — mod-switch first");
        debug_assert_eq!(a.level, self.level, "begin() at the operand level first");
        debug_assert!(a.c0.is_ntt && b.c0.is_ntt);
        self.d0.as_mut().expect("begin() first").mul_acc_ntt(&a.c0, &b.c0);
        self.d1.as_mut().expect("begin() first").mul_acc2_ntt(&a.c0, &b.c1, &a.c1, &b.c0);
        self.d2.as_mut().expect("begin() first").mul_acc_ntt(&a.c1, &b.c1);
        self.has_d2 = true;
    }

    /// MultCP accumulate: `(d0, d1) += (x0·w, x1·w)` against the cached
    /// evaluation-form weight (degree-1, relin-free).
    pub fn mac_cp_into(&mut self, x: &BgvCiphertext, w: &CachedPlaintext) {
        debug_assert_eq!(x.level, self.level, "begin() at the operand level first");
        debug_assert!(x.c0.is_ntt);
        let p = w.ntt_at(self.level);
        self.d0.as_mut().expect("begin() first").mul_acc_ntt(&x.c0, p);
        self.d1.as_mut().expect("begin() first").mul_acc_ntt(&x.c1, p);
    }

    /// Finalize the accumulated row into `out`: relinearize the degree-2
    /// component **once** (the lazy-relin win: one relin per row instead of
    /// one per `Cc` term), writing into `out`'s existing buffers — no heap
    /// allocation. `out` must be a warm ciphertext at this row's level.
    pub fn relin_finalize_into(&mut self, out: &mut BgvCiphertext, rlk: &RelinKey, ctx: &BgvContext) {
        let level = self.level;
        let d0 = self.d0.as_mut().expect("begin() first");
        let d1 = self.d1.as_mut().expect("begin() first");
        if self.has_d2 {
            let d2 = self.d2.as_mut().expect("begin() first");
            let dig = self.dig.as_mut().expect("begin() first");
            d2.to_coeff();
            let rctx = ctx.ctx_at(level);
            let n = rctx.n;
            for i in 0..level {
                // digit polynomial = centered [d2]_{q_i}, lifted to all limbs
                // (same decomposition as the reference `mul_assign`, built
                // into the reusable `dig` buffer instead of fresh Vecs).
                let qi = rctx.primes[i];
                let half = qi / 2;
                dig.is_ntt = false;
                for l in 0..level {
                    let p = rctx.primes[l];
                    let br = rctx.ntts[l].barrett();
                    for j in 0..n {
                        // Centered digit c = [d2]_{q_i} ∈ (−q_i/2, q_i/2],
                        // lifted to Z_p with a Barrett reduction instead of
                        // `u64 %`. Replicates the old `%`-based lift exactly,
                        // including the p − 0 = p representative for negative
                        // multiples of p (invisible after the forward NTT).
                        let v = d2.res[i][j];
                        let (c_abs, neg) = if v > half { (qi - v, true) } else { (v, false) };
                        let r = barrett_reduce(c_abs, p, br);
                        dig.res[l][j] = if neg { p - r } else { r };
                    }
                }
                dig.to_ntt();
                let (k0, k1) = &rlk.rows[level - 1][i];
                d0.mul_acc_ntt(dig, k0);
                d1.mul_acc_ntt(dig, k1);
            }
        }
        debug_assert_eq!(out.c0.level, level, "warm output at the row level required");
        out.c0.copy_from(d0);
        out.c1.copy_from(d1);
        out.level = level;
    }

    /// Allocating convenience wrapper around [`Self::relin_finalize_into`].
    pub fn relin_finalize(&mut self, rlk: &RelinKey, ctx: &BgvContext) -> BgvCiphertext {
        let rctx = ctx.ctx_at(self.level);
        let mut out = BgvCiphertext {
            c0: RnsPoly::zero(rctx, self.level),
            c1: RnsPoly::zero(rctx, self.level),
            level: self.level,
        };
        self.relin_finalize_into(&mut out, rlk, ctx);
        out
    }
}

impl Default for BgvScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Run one whole MAC row through `scratch`: accumulate every term, then
/// relinearize once. The lazy-relin replacement for the per-term
/// `mul_assign` + `add_assign` reference loop; bit-identical decryption is
/// asserted by `tests/bgv_mac_equivalence.rs`.
pub fn mac_row(
    scratch: &mut BgvScratch,
    terms: &[MacTerm],
    rlk: &RelinKey,
    ctx: &BgvContext,
) -> BgvCiphertext {
    assert!(!terms.is_empty(), "a MAC row needs at least one term");
    let level = terms[0].level();
    scratch.begin(ctx.ctx_at(level), level);
    for t in terms {
        match *t {
            MacTerm::Cc(a, b) => scratch.mac_cc_tensor_into(a, b),
            MacTerm::Cp(x, w) => scratch.mac_cp_into(x, w),
        }
    }
    scratch.relin_finalize(rlk, ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bgv::keys::BgvSecretKey;
    use crate::bgv::params::BgvParams;
    use crate::math::rng::GlyphRng;
    use std::sync::Arc;

    struct Fx {
        ctx: Arc<BgvContext>,
        sk: BgvSecretKey,
        rlk: RelinKey,
        rng: GlyphRng,
    }

    fn fixture(seed: u64) -> Fx {
        let ctx = BgvContext::new(BgvParams::test_params());
        let mut rng = GlyphRng::new(seed);
        let sk = BgvSecretKey::generate(&ctx, &mut rng);
        let rlk = RelinKey::generate(&sk, &mut rng);
        Fx { ctx, sk, rlk, rng }
    }

    fn enc(f: &mut Fx, vals: &[i64]) -> BgvCiphertext {
        let pt = Plaintext::encode_batch(vals, &f.ctx.params);
        f.sk.encrypt(&pt, &mut f.rng)
    }

    fn dec(f: &Fx, ct: &BgvCiphertext, k: usize) -> Vec<i64> {
        f.sk.decrypt(ct).decode_batch(k)
    }

    #[test]
    fn add_sub_cc() {
        let mut f = fixture(1);
        let a = enc(&mut f, &[10, -20, 30]);
        let b = enc(&mut f, &[1, 2, -3]);
        let mut c = a.clone();
        c.add_assign(&b);
        assert_eq!(dec(&f, &c, 3), vec![11, -18, 27]);
        c.sub_assign(&b);
        assert_eq!(dec(&f, &c, 3), vec![10, -20, 30]);
    }

    #[test]
    fn mult_cp_batchwise_scalar() {
        let mut f = fixture(2);
        let mut x = enc(&mut f, &[5, -7, 11, 0]);
        let w = Plaintext::encode_scalar(-6, &f.ctx.params);
        x.mul_plain_assign(&w, &f.ctx);
        assert_eq!(dec(&f, &x, 4), vec![-30, 42, -66, 0]);
    }

    #[test]
    fn mult_cc_constant_weight_times_batch() {
        // The Glyph MAC shape: weight ct (constant poly) × value ct (batch
        // in coefficients) = batch-wise scalar product.
        let mut f = fixture(3);
        let mut w = enc(&mut f, &[9]); // constant poly: only coeff 0
        let x = enc(&mut f, &[3, -4, 120, -128]);
        w.mul_assign(&x, &f.rlk, &f.ctx);
        assert_eq!(dec(&f, &w, 4), vec![27, -36, 1080, -1152]);
    }

    #[test]
    fn mac_accumulation_matches_plain() {
        // Σ_i w_i ⊗ x_i over 16 terms — one FC neuron on a batch of 4.
        let mut f = fixture(4);
        let mut rng2 = GlyphRng::new(777);
        let mut acc: Option<BgvCiphertext> = None;
        let mut want = vec![0i64; 4];
        for _ in 0..16 {
            let wv = (rng2.uniform_mod(255) as i64) - 127;
            let xs: Vec<i64> = (0..4).map(|_| (rng2.uniform_mod(255) as i64) - 127).collect();
            for b in 0..4 {
                want[b] += wv * xs[b];
            }
            let mut wct = enc(&mut f, &[wv]);
            let xct = enc(&mut f, &xs);
            wct.mul_assign(&xct, &f.rlk, &f.ctx);
            match &mut acc {
                None => acc = Some(wct),
                Some(a) => a.add_assign(&wct),
            }
        }
        assert_eq!(dec(&f, &acc.unwrap(), 4), want);
    }

    #[test]
    fn mod_switch_preserves_plaintext() {
        let mut f = fixture(5);
        let vals = vec![1234i64, -4321, 77];
        let mut ct = enc(&mut f, &vals);
        ct.mod_switch_down(&f.ctx);
        assert_eq!(ct.level, f.ctx.top_level() - 1);
        assert_eq!(dec(&f, &ct, 3), vals);
    }

    #[test]
    fn mod_switch_shrinks_post_mult_noise() {
        // After a MultCC the noise is large; dropping a limb divides it by
        // ~q_last (plus a small t-sized rounding term).
        let mut f = fixture(55);
        let mut a = enc(&mut f, &[99, -2]);
        let w = enc(&mut f, &[3]); // constant poly
        a.mul_assign(&w, &f.rlk, &f.ctx);
        let noise_before = f.sk.noise_magnitude(&a);
        a.mod_switch_down(&f.ctx);
        let noise_after = f.sk.noise_magnitude(&a);
        assert_eq!(dec(&f, &a, 2), vec![297, -6]);
        assert!(noise_after < noise_before / 1000, "{noise_after} !< {noise_before}/1000");
    }

    #[test]
    fn depth_two_with_mod_switch() {
        // Batch ct × scalar weight × scalar weight (batch-wise semantics
        // require constant-poly multiplicands — DESIGN.md §2.1).
        let mut f = fixture(6);
        let mut a = enc(&mut f, &[12, -5]);
        let b = enc(&mut f, &[-3]);
        a.mul_assign(&b, &f.rlk, &f.ctx); // depth 1
        a.mod_switch_down(&f.ctx);
        let mut c = enc(&mut f, &[2]);
        c.mod_switch_to(a.level, &f.ctx);
        a.mul_assign(&c, &f.rlk, &f.ctx); // depth 2
        assert_eq!(dec(&f, &a, 2), vec![12 * -3 * 2, -5 * -3 * 2]);
    }

    #[test]
    fn batch_times_batch_is_negacyclic_convolution() {
        // Documents the §2.1 constraint: two batch-packed operands convolve.
        let mut f = fixture(66);
        let mut a = enc(&mut f, &[2, 3]);
        let b = enc(&mut f, &[5, 7]);
        a.mul_assign(&b, &f.rlk, &f.ctx);
        // (2 + 3X)(5 + 7X) = 10 + 29X + 21X²
        let got = dec(&f, &a, 3);
        assert_eq!(got, vec![10, 29, 21]);
    }

    #[test]
    fn trivial_ciphertext_ops() {
        let mut f = fixture(7);
        let pt = Plaintext::encode_batch(&[100, -100], &f.ctx.params);
        let triv = BgvCiphertext::trivial(&pt, &f.ctx, f.ctx.top_level());
        assert_eq!(dec(&f, &triv, 2), vec![100, -100]);
        let mut x = enc(&mut f, &[1, 1]);
        x.add_assign(&triv);
        assert_eq!(dec(&f, &x, 2), vec![101, -99]);
    }

    #[test]
    fn add_plain_and_small_scalar() {
        let mut f = fixture(8);
        let mut x = enc(&mut f, &[10, 20]);
        let pt = Plaintext::encode_batch(&[-3, 4], &f.ctx.params);
        x.add_plain(&pt, &f.ctx);
        assert_eq!(dec(&f, &x, 2), vec![7, 24]);
        x.small_scalar_mul_assign(-2, &f.ctx);
        assert_eq!(dec(&f, &x, 2), vec![-14, -48]);
    }

    #[test]
    fn negation() {
        let mut f = fixture(9);
        let mut x = enc(&mut f, &[42, -17]);
        x.neg_assign();
        assert_eq!(dec(&f, &x, 2), vec![-42, 17]);
    }

    #[test]
    fn cached_mult_cp_matches_reference() {
        let mut f = fixture(11);
        let x = enc(&mut f, &[5, -7, 11, 0]);
        let w = Plaintext::encode_scalar(-6, &f.ctx.params);
        let cached = CachedPlaintext::new(w.clone(), &f.ctx);
        let mut a = x.clone();
        a.mul_plain_assign(&w, &f.ctx);
        let mut b = x.clone();
        b.mul_plain_cached_assign(&cached);
        // identical ciphertexts, not merely identical decryptions: the
        // cached lift is the same polynomial the reference path computes
        for i in 0..a.level {
            assert_eq!(a.c0.res[i], b.c0.res[i], "limb {i}");
            assert_eq!(a.c1.res[i], b.c1.res[i], "limb {i}");
        }
        assert_eq!(dec(&f, &b, 4), vec![-30, 42, -66, 0]);
    }

    #[test]
    fn scratch_mac_row_decrypts_like_reference_loop() {
        // 12 Cc terms + 4 Cp terms through the lazy-relin row vs the
        // per-term reference accumulation.
        let mut f = fixture(12);
        let mut rng2 = GlyphRng::new(4096);
        let mut terms_w = Vec::new();
        let mut terms_x = Vec::new();
        let mut plain_w = Vec::new();
        let mut want = vec![0i64; 4];
        for k in 0..16 {
            let wv = (rng2.uniform_mod(31) as i64) - 15;
            let xs: Vec<i64> = (0..4).map(|_| (rng2.uniform_mod(255) as i64) - 127).collect();
            for b in 0..4 {
                want[b] += wv * xs[b];
            }
            terms_x.push(enc(&mut f, &xs));
            if k % 4 == 3 {
                plain_w.push(Some(CachedPlaintext::scalar(wv, &f.ctx)));
                terms_w.push(None);
            } else {
                plain_w.push(None);
                terms_w.push(Some(enc(&mut f, &[wv])));
            }
        }
        // reference: per-term relin + add
        let mut reference: Option<BgvCiphertext> = None;
        for k in 0..16 {
            let term = match (&terms_w[k], &plain_w[k]) {
                (Some(wct), None) => {
                    let mut t = wct.clone();
                    t.mul_assign(&terms_x[k], &f.rlk, &f.ctx);
                    t
                }
                (None, Some(wpt)) => {
                    let mut t = terms_x[k].clone();
                    t.mul_plain_cached_assign(wpt);
                    t
                }
                _ => unreachable!(),
            };
            match &mut reference {
                None => reference = Some(term),
                Some(a) => a.add_assign(&term),
            }
        }
        // lazy: one scratch row, one relin
        let row: Vec<MacTerm> = (0..16)
            .map(|k| match (&terms_w[k], &plain_w[k]) {
                (Some(wct), None) => MacTerm::Cc(wct, &terms_x[k]),
                (None, Some(wpt)) => MacTerm::Cp(&terms_x[k], wpt),
                _ => unreachable!(),
            })
            .collect();
        let mut scratch = BgvScratch::new();
        let fast = mac_row(&mut scratch, &row, &f.rlk, &f.ctx);
        assert_eq!(dec(&f, &fast, 4), want);
        assert_eq!(dec(&f, &reference.unwrap(), 4), want);
    }

    #[test]
    fn scratch_reuse_across_rows_is_consistent() {
        // The same scratch must produce correct rows back to back (warm
        // buffers fully re-zeroed by begin()).
        let mut f = fixture(13);
        let mut scratch = BgvScratch::new();
        for round in 0..3i64 {
            let w = enc(&mut f, &[round + 2]);
            let x = enc(&mut f, &[10, -20]);
            let row = [MacTerm::Cc(&w, &x)];
            let out = mac_row(&mut scratch, &row, &f.rlk, &f.ctx);
            assert_eq!(dec(&f, &out, 2), vec![10 * (round + 2), -20 * (round + 2)], "round {round}");
        }
    }

    #[test]
    fn relin_finalize_into_reuses_warm_output() {
        let mut f = fixture(14);
        let w = enc(&mut f, &[3]);
        let x = enc(&mut f, &[7, -9]);
        let mut scratch = BgvScratch::new();
        let mut out = mac_row(&mut scratch, &[MacTerm::Cc(&w, &x)], &f.rlk, &f.ctx);
        // rerun with different operands into the warm output
        let w2 = enc(&mut f, &[-5]);
        scratch.begin(f.ctx.ctx_at(w2.level), w2.level);
        scratch.mac_cc_tensor_into(&w2, &x);
        scratch.relin_finalize_into(&mut out, &f.rlk, &f.ctx);
        assert_eq!(dec(&f, &out, 2), vec![-35, 45]);
    }

    #[test]
    fn pure_cp_row_skips_relin_and_matches() {
        let mut f = fixture(15);
        let x1 = enc(&mut f, &[4, -3]);
        let x2 = enc(&mut f, &[1, 9]);
        let w1 = CachedPlaintext::scalar(5, &f.ctx);
        let w2 = CachedPlaintext::scalar(-2, &f.ctx);
        let mut scratch = BgvScratch::new();
        let out = mac_row(
            &mut scratch,
            &[MacTerm::Cp(&x1, &w1), MacTerm::Cp(&x2, &w2)],
            &f.rlk,
            &f.ctx,
        );
        // (4·5 + 1·−2, −3·5 + 9·−2)
        assert_eq!(dec(&f, &out, 2), vec![18, -33]);
    }

    #[test]
    fn noise_after_multcc_within_budget() {
        let mut f = fixture(10);
        let mut a = enc(&mut f, &[127]);
        let b = enc(&mut f, &[-127]);
        a.mul_assign(&b, &f.rlk, &f.ctx);
        let noise = f.sk.noise_magnitude(&a);
        // must be far below q/2 ≈ 2^95
        assert!(noise < 1i128 << 80, "noise 2^{:.1}", (noise as f64).log2());
        assert_eq!(dec(&f, &a, 1), vec![-16129]);
    }
}
