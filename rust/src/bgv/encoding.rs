//! Plaintext encoding: batch-in-coefficients packing (DESIGN.md §2.1).
//!
//! * A *value* plaintext packs one mini-batch of signed fixed-point scalars:
//!   sample `b` lives at coefficient `X^b`.
//! * A *weight* plaintext is the constant polynomial `w`: multiplying by a
//!   degree-0 polynomial scales every coefficient, i.e. a batch-wise scalar
//!   MAC — semantically identical to the paper's slot packing.

use super::keys::BgvContext;
use super::params::BgvParams;
use crate::math::poly::{RnsContext, RnsPoly};
use std::sync::Arc;

/// A plaintext polynomial over `Z_t`, kept as centered signed values.
#[derive(Clone, Debug)]
pub struct Plaintext {
    /// Coefficients as centered representatives in `(−t/2, t/2]`.
    pub coeffs: Vec<i64>,
    pub t: u64,
}

impl Plaintext {
    /// Pack a batch of signed values (coefficient `b` = sample `b`).
    /// Values must fit in `(−t/2, t/2]`.
    pub fn encode_batch(values: &[i64], params: &BgvParams) -> Self {
        assert!(values.len() <= params.n, "batch exceeds ring capacity");
        let half = (params.t / 2) as i64;
        let mut coeffs = vec![0i64; params.n];
        for (i, &v) in values.iter().enumerate() {
            assert!(v >= -half && v <= half, "value {v} out of plaintext range ±{half}");
            coeffs[i] = v;
        }
        Plaintext { coeffs, t: params.t }
    }

    /// The constant polynomial `w` (a weight scalar).
    pub fn encode_scalar(w: i64, params: &BgvParams) -> Self {
        Self::encode_batch(&[w], params)
    }

    /// Read back the first `count` batch lanes.
    pub fn decode_batch(&self, count: usize) -> Vec<i64> {
        self.coeffs[..count].to_vec()
    }

    /// Centered reduction of an arbitrary integer into the plaintext ring.
    pub fn center(v: u64, t: u64) -> i64 {
        let v = v % t;
        if v > t / 2 {
            v as i64 - t as i64
        } else {
            v as i64
        }
    }

    /// Lift to an RNS polynomial at `level` limbs.
    pub fn to_rns(&self, ctx: &Arc<RnsContext>, level: usize) -> RnsPoly {
        RnsPoly::from_signed(ctx, &self.coeffs, level)
    }
}

/// A plaintext with its per-level NTT-domain RNS lifts precomputed once at
/// construction — the evaluation-form weight cache behind MultCP. The old
/// hot path redid `to_rns` + a full forward NTT on *every* ciphertext ×
/// plaintext product; with the cache a MultCP is a pure pointwise pass
/// (EXPERIMENTS.md §BGV MAC perf log).
pub struct CachedPlaintext {
    /// The underlying plaintext (kept for inspection / re-encoding).
    pub pt: Plaintext,
    /// `ntt[ℓ−1]` = the NTT-form lift at level ℓ (ℓ active limbs).
    ntt: Vec<RnsPoly>,
}

impl CachedPlaintext {
    /// Build the evaluation-form cache for every level of the chain.
    pub fn new(pt: Plaintext, ctx: &BgvContext) -> Self {
        let ntt = (1..=ctx.top_level())
            .map(|level| {
                let mut p = pt.to_rns(ctx.ctx_at(level), level);
                p.to_ntt();
                p
            })
            .collect();
        CachedPlaintext { pt, ntt }
    }

    /// Encode-and-cache a weight scalar (the constant polynomial `w`).
    pub fn scalar(w: i64, ctx: &BgvContext) -> Self {
        Self::new(Plaintext::encode_scalar(w, &ctx.params), ctx)
    }

    /// The cached NTT-form lift at `level` active limbs.
    pub fn ntt_at(&self, level: usize) -> &RnsPoly {
        &self.ntt[level - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let p = BgvParams::test_params();
        let vals: Vec<i64> = vec![0, 1, -1, 127, -128, 32000, -32000];
        let pt = Plaintext::encode_batch(&vals, &p);
        assert_eq!(pt.decode_batch(vals.len()), vals);
        // untouched lanes are zero
        assert_eq!(pt.coeffs[vals.len()], 0);
    }

    #[test]
    fn scalar_is_constant_poly() {
        let p = BgvParams::test_params();
        let pt = Plaintext::encode_scalar(-42, &p);
        assert_eq!(pt.coeffs[0], -42);
        assert!(pt.coeffs[1..].iter().all(|&c| c == 0));
    }

    #[test]
    #[should_panic(expected = "out of plaintext range")]
    fn overflow_is_rejected() {
        let p = BgvParams::test_params();
        let _ = Plaintext::encode_batch(&[(p.t / 2) as i64 + 1], &p);
    }

    #[test]
    fn cached_plaintext_matches_fresh_lift_at_every_level() {
        let ctx = BgvContext::new(BgvParams::test_params());
        let pt = Plaintext::encode_batch(&[5, -6, 7], &ctx.params);
        let cached = CachedPlaintext::new(pt.clone(), &ctx);
        for level in 1..=ctx.top_level() {
            let mut fresh = pt.to_rns(ctx.ctx_at(level), level);
            fresh.to_ntt();
            let c = cached.ntt_at(level);
            assert!(c.is_ntt);
            assert_eq!(c.level, level);
            for i in 0..level {
                assert_eq!(c.res[i], fresh.res[i], "level {level} limb {i}");
            }
        }
    }

    #[test]
    fn center_reduces_symmetrically() {
        assert_eq!(Plaintext::center(0, 256), 0);
        assert_eq!(Plaintext::center(255, 256), -1);
        assert_eq!(Plaintext::center(128, 256), 128);
        assert_eq!(Plaintext::center(129, 256), -127);
    }
}
