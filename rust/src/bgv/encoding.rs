//! Plaintext encoding: batch-in-coefficients packing (DESIGN.md §2.1).
//!
//! * A *value* plaintext packs one mini-batch of signed fixed-point scalars:
//!   sample `b` lives at coefficient `X^b`.
//! * A *weight* plaintext is the constant polynomial `w`: multiplying by a
//!   degree-0 polynomial scales every coefficient, i.e. a batch-wise scalar
//!   MAC — semantically identical to the paper's slot packing.

use super::params::BgvParams;
use crate::math::poly::{RnsContext, RnsPoly};
use std::sync::Arc;

/// A plaintext polynomial over `Z_t`, kept as centered signed values.
#[derive(Clone, Debug)]
pub struct Plaintext {
    /// Coefficients as centered representatives in `(−t/2, t/2]`.
    pub coeffs: Vec<i64>,
    pub t: u64,
}

impl Plaintext {
    /// Pack a batch of signed values (coefficient `b` = sample `b`).
    /// Values must fit in `(−t/2, t/2]`.
    pub fn encode_batch(values: &[i64], params: &BgvParams) -> Self {
        assert!(values.len() <= params.n, "batch exceeds ring capacity");
        let half = (params.t / 2) as i64;
        let mut coeffs = vec![0i64; params.n];
        for (i, &v) in values.iter().enumerate() {
            assert!(v >= -half && v <= half, "value {v} out of plaintext range ±{half}");
            coeffs[i] = v;
        }
        Plaintext { coeffs, t: params.t }
    }

    /// The constant polynomial `w` (a weight scalar).
    pub fn encode_scalar(w: i64, params: &BgvParams) -> Self {
        Self::encode_batch(&[w], params)
    }

    /// Read back the first `count` batch lanes.
    pub fn decode_batch(&self, count: usize) -> Vec<i64> {
        self.coeffs[..count].to_vec()
    }

    /// Centered reduction of an arbitrary integer into the plaintext ring.
    pub fn center(v: u64, t: u64) -> i64 {
        let v = v % t;
        if v > t / 2 {
            v as i64 - t as i64
        } else {
            v as i64
        }
    }

    /// Lift to an RNS polynomial at `level` limbs.
    pub fn to_rns(&self, ctx: &Arc<RnsContext>, level: usize) -> RnsPoly {
        RnsPoly::from_signed(ctx, &self.coeffs, level)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let p = BgvParams::test_params();
        let vals: Vec<i64> = vec![0, 1, -1, 127, -128, 32000, -32000];
        let pt = Plaintext::encode_batch(&vals, &p);
        assert_eq!(pt.decode_batch(vals.len()), vals);
        // untouched lanes are zero
        assert_eq!(pt.coeffs[vals.len()], 0);
    }

    #[test]
    fn scalar_is_constant_poly() {
        let p = BgvParams::test_params();
        let pt = Plaintext::encode_scalar(-42, &p);
        assert_eq!(pt.coeffs[0], -42);
        assert!(pt.coeffs[1..].iter().all(|&c| c == 0));
    }

    #[test]
    #[should_panic(expected = "out of plaintext range")]
    fn overflow_is_rejected() {
        let p = BgvParams::test_params();
        let _ = Plaintext::encode_batch(&[(p.t / 2) as i64 + 1], &p);
    }

    #[test]
    fn center_reduces_symmetrically() {
        assert_eq!(Plaintext::center(0, 256), 0);
        assert_eq!(Plaintext::center(255, 256), -1);
        assert_eq!(Plaintext::center(128, 256), 128);
        assert_eq!(Plaintext::center(129, 256), -127);
    }
}
