//! Plaintext encoding: batch-in-coefficients packing (DESIGN.md §2.1).
//!
//! * A *value* plaintext packs one mini-batch of signed fixed-point scalars:
//!   sample `b` lives at coefficient `X^b`.
//! * A *weight* plaintext is the constant polynomial `w`: multiplying by a
//!   degree-0 polynomial scales every coefficient, i.e. a batch-wise scalar
//!   MAC — semantically identical to the paper's slot packing.

use super::keys::BgvContext;
use super::params::BgvParams;
use crate::math::poly::{RnsContext, RnsPoly};
use std::fmt;
use std::sync::Arc;

/// Plaintext-encoding validation failure: every encode/decode entry point
/// checks its inputs against the ring geometry up front and reports *what*
/// overflowed instead of tripping a bare assert deep inside the packing
/// loop (the `SwitchError` convention).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EncodingError {
    /// More batch values than the ring has coefficient slots.
    BatchTooLarge { len: usize, capacity: usize },
    /// A value outside the centered plaintext range `[−t/2, t/2]`.
    ValueOutOfRange { index: usize, value: i64, half: i64 },
    /// A decode asked for more lanes than the polynomial holds.
    DecodeTooWide { count: usize, capacity: usize },
    /// A packed (strided) layout whose feature lanes or interleaved batch
    /// overrun the ring. `try_encode_batch` only ever checked the *total*
    /// slot count; a strided layout must additionally keep every feature
    /// lane (`features · stride` slots) inside the ring and every sample
    /// inside its feature's stride window.
    StrideOverrun { features: usize, stride: usize, batch: usize, capacity: usize },
}

impl fmt::Display for EncodingError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EncodingError::BatchTooLarge { len, capacity } => write!(
                f,
                "batch of {len} values exceeds the ring capacity of {capacity} coefficient slots"
            ),
            EncodingError::ValueOutOfRange { index, value, half } => write!(
                f,
                "value {value} at batch index {index} outside the plaintext range ±{half} \
                 (t/2 itself is the inclusive boundary)"
            ),
            EncodingError::DecodeTooWide { count, capacity } => write!(
                f,
                "decode of {count} lanes exceeds the {capacity} coefficients the plaintext holds"
            ),
            EncodingError::StrideOverrun { features, stride, batch, capacity } => write!(
                f,
                "packed layout of {features} feature lanes × {batch} samples at slot stride \
                 {stride} overruns the ring: it needs {} of {capacity} coefficient slots and the \
                 batch must fit within one stride window",
                features * stride
            ),
        }
    }
}

impl std::error::Error for EncodingError {}

/// A plaintext polynomial over `Z_t`, kept as centered signed values.
#[derive(Clone, Debug)]
pub struct Plaintext {
    /// Coefficients as centered representatives in `(−t/2, t/2]`.
    pub coeffs: Vec<i64>,
    pub t: u64,
}

impl Plaintext {
    /// Pack a batch of signed values (coefficient `b` = sample `b`),
    /// validating capacity and range. Values must fit in `[−t/2, t/2]`.
    pub fn try_encode_batch(values: &[i64], params: &BgvParams) -> Result<Self, EncodingError> {
        if values.len() > params.n {
            return Err(EncodingError::BatchTooLarge { len: values.len(), capacity: params.n });
        }
        let half = (params.t / 2) as i64;
        let mut coeffs = vec![0i64; params.n];
        for (i, &v) in values.iter().enumerate() {
            if v < -half || v > half {
                return Err(EncodingError::ValueOutOfRange { index: i, value: v, half });
            }
            coeffs[i] = v;
        }
        Ok(Plaintext { coeffs, t: params.t })
    }

    /// [`Self::try_encode_batch`], panicking with the descriptive error
    /// (the infallible-by-construction call sites' convenience form).
    pub fn encode_batch(values: &[i64], params: &BgvParams) -> Self {
        Self::try_encode_batch(values, params).unwrap_or_else(|e| panic!("encode_batch: {e}"))
    }

    /// The constant polynomial `w` (a weight scalar).
    pub fn encode_scalar(w: i64, params: &BgvParams) -> Self {
        Self::encode_batch(&[w], params)
    }

    /// Read back the first `count` batch lanes, validating against the
    /// polynomial's coefficient count.
    pub fn try_decode_batch(&self, count: usize) -> Result<Vec<i64>, EncodingError> {
        if count > self.coeffs.len() {
            return Err(EncodingError::DecodeTooWide { count, capacity: self.coeffs.len() });
        }
        Ok(self.coeffs[..count].to_vec())
    }

    /// [`Self::try_decode_batch`], panicking with the descriptive error.
    pub fn decode_batch(&self, count: usize) -> Vec<i64> {
        self.try_decode_batch(count).unwrap_or_else(|e| panic!("decode_batch: {e}"))
    }

    /// Pack per-feature sample columns at a fixed slot stride: feature `j`,
    /// sample `b` lands at coefficient `j·stride + b` (the cross-sample
    /// SIMD layout; `PackedLayout` in `nn::tensor`). Unlike
    /// [`Self::try_encode_batch`] — which only validates against the
    /// *total* slot count — this checks the strided geometry: every
    /// feature lane must fit inside the ring (`features · stride ≤ n`)
    /// and the interleaved batch inside one stride window
    /// (`batch ≤ stride`), rejecting overruns with a descriptive
    /// [`EncodingError::StrideOverrun`] instead of silently folding lanes
    /// together.
    pub fn try_encode_strided(
        cols: &[Vec<i64>],
        stride: usize,
        params: &BgvParams,
    ) -> Result<Self, EncodingError> {
        let features = cols.len();
        let batch = cols.first().map_or(0, Vec::len);
        if batch > stride || features * stride > params.n {
            return Err(EncodingError::StrideOverrun {
                features,
                stride,
                batch,
                capacity: params.n,
            });
        }
        let half = (params.t / 2) as i64;
        let mut coeffs = vec![0i64; params.n];
        for (j, col) in cols.iter().enumerate() {
            assert_eq!(col.len(), batch, "every feature column spans the same batch");
            for (b, &v) in col.iter().enumerate() {
                if v < -half || v > half {
                    return Err(EncodingError::ValueOutOfRange {
                        index: j * batch + b,
                        value: v,
                        half,
                    });
                }
                coeffs[j * stride + b] = v;
            }
        }
        Ok(Plaintext { coeffs, t: params.t })
    }

    /// [`Self::try_encode_strided`], panicking with the descriptive error.
    pub fn encode_strided(cols: &[Vec<i64>], stride: usize, params: &BgvParams) -> Self {
        Self::try_encode_strided(cols, stride, params)
            .unwrap_or_else(|e| panic!("encode_strided: {e}"))
    }

    /// Read `features` per-feature sample columns back out of a strided
    /// packing (the inverse of [`Self::try_encode_strided`]).
    pub fn try_decode_strided(
        &self,
        stride: usize,
        features: usize,
        batch: usize,
    ) -> Result<Vec<Vec<i64>>, EncodingError> {
        if batch > stride || features * stride > self.coeffs.len() {
            return Err(EncodingError::StrideOverrun {
                features,
                stride,
                batch,
                capacity: self.coeffs.len(),
            });
        }
        Ok((0..features)
            .map(|j| self.coeffs[j * stride..j * stride + batch].to_vec())
            .collect())
    }

    /// Centered reduction of an arbitrary integer into the plaintext ring.
    pub fn center(v: u64, t: u64) -> i64 {
        let v = v % t;
        if v > t / 2 {
            v as i64 - t as i64
        } else {
            v as i64
        }
    }

    /// Lift to an RNS polynomial at `level` limbs.
    pub fn to_rns(&self, ctx: &Arc<RnsContext>, level: usize) -> RnsPoly {
        RnsPoly::from_signed(ctx, &self.coeffs, level)
    }
}

/// A plaintext with its per-level NTT-domain RNS lifts precomputed once at
/// construction — the evaluation-form weight cache behind MultCP. The old
/// hot path redid `to_rns` + a full forward NTT on *every* ciphertext ×
/// plaintext product; with the cache a MultCP is a pure pointwise pass
/// (EXPERIMENTS.md §BGV MAC perf log).
pub struct CachedPlaintext {
    /// The underlying plaintext (kept for inspection / re-encoding).
    pub pt: Plaintext,
    /// `ntt[ℓ−1]` = the NTT-form lift at level ℓ (ℓ active limbs).
    ntt: Vec<RnsPoly>,
}

impl CachedPlaintext {
    /// Build the evaluation-form cache for every level of the chain.
    pub fn new(pt: Plaintext, ctx: &BgvContext) -> Self {
        let ntt = (1..=ctx.top_level())
            .map(|level| {
                let mut p = pt.to_rns(ctx.ctx_at(level), level);
                p.to_ntt();
                p
            })
            .collect();
        CachedPlaintext { pt, ntt }
    }

    /// Encode-and-cache a weight scalar (the constant polynomial `w`).
    pub fn scalar(w: i64, ctx: &BgvContext) -> Self {
        Self::new(Plaintext::encode_scalar(w, &ctx.params), ctx)
    }

    /// The cached NTT-form lift at `level` active limbs.
    pub fn ntt_at(&self, level: usize) -> &RnsPoly {
        &self.ntt[level - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let p = BgvParams::test_params();
        let vals: Vec<i64> = vec![0, 1, -1, 127, -128, 32000, -32000];
        let pt = Plaintext::encode_batch(&vals, &p);
        assert_eq!(pt.decode_batch(vals.len()), vals);
        // untouched lanes are zero
        assert_eq!(pt.coeffs[vals.len()], 0);
    }

    #[test]
    fn scalar_is_constant_poly() {
        let p = BgvParams::test_params();
        let pt = Plaintext::encode_scalar(-42, &p);
        assert_eq!(pt.coeffs[0], -42);
        assert!(pt.coeffs[1..].iter().all(|&c| c == 0));
    }

    #[test]
    fn overflow_is_a_descriptive_error() {
        let p = BgvParams::test_params();
        let half = (p.t / 2) as i64;
        let err = Plaintext::try_encode_batch(&[0, half + 1], &p).err().expect("must reject");
        assert_eq!(err, EncodingError::ValueOutOfRange { index: 1, value: half + 1, half });
        let msg = err.to_string();
        assert!(msg.contains(&(half + 1).to_string()) && msg.contains("index 1"), "{msg}");
    }

    #[test]
    fn over_capacity_batch_is_a_descriptive_error() {
        let p = BgvParams::test_params();
        let too_many = vec![1i64; p.n + 3];
        let err = Plaintext::try_encode_batch(&too_many, &p).err().expect("must reject");
        assert_eq!(err, EncodingError::BatchTooLarge { len: p.n + 3, capacity: p.n });
        let msg = err.to_string();
        assert!(msg.contains(&p.n.to_string()) && msg.contains(&(p.n + 3).to_string()), "{msg}");
    }

    #[test]
    fn half_t_boundary_values_encode_and_roundtrip() {
        // ±t/2 are the inclusive range edges; both are accepted and decode
        // back unchanged (they are congruent mod t — the clear backend
        // canonicalizes, decryption centers to +t/2).
        let p = BgvParams::test_params();
        let half = (p.t / 2) as i64;
        let pt = Plaintext::try_encode_batch(&[half, -half], &p).expect("boundary is in range");
        assert_eq!(pt.try_decode_batch(2).unwrap(), vec![half, -half]);
    }

    #[test]
    fn decode_past_capacity_is_a_descriptive_error() {
        let p = BgvParams::test_params();
        let pt = Plaintext::encode_batch(&[1, 2], &p);
        let err = pt.try_decode_batch(p.n + 1).err().expect("must reject");
        assert_eq!(err, EncodingError::DecodeTooWide { count: p.n + 1, capacity: p.n });
        assert!(err.to_string().contains(&(p.n + 1).to_string()));
    }

    #[test]
    fn strided_encode_decode_roundtrip() {
        let p = BgvParams::test_params();
        let cols = vec![vec![1, -2, 3], vec![-4, 5, -6], vec![7, 8, 9]];
        let pt = Plaintext::encode_strided(&cols, 8, &p);
        // feature j, sample b at coefficient j·8 + b; everything else zero
        assert_eq!(&pt.coeffs[..3], &[1, -2, 3]);
        assert_eq!(&pt.coeffs[8..11], &[-4, 5, -6]);
        assert_eq!(&pt.coeffs[16..19], &[7, 8, 9]);
        assert_eq!(pt.coeffs.iter().filter(|&&c| c != 0).count(), 9);
        assert_eq!(pt.try_decode_strided(8, 3, 3).unwrap(), cols);
    }

    #[test]
    fn strided_encode_boundary_exactly_full_and_one_over() {
        let p = BgvParams::test_params();
        let stride = 8;
        let full = p.n / stride;
        // exactly full: n/stride feature lanes, batch = stride — accepted
        let cols = vec![vec![1i64; stride]; full];
        let pt = Plaintext::try_encode_strided(&cols, stride, &p).expect("exactly full fits");
        assert_eq!(pt.try_decode_strided(stride, full, stride).unwrap(), cols);

        // one feature lane over: stride × features overruns the ring
        let cols = vec![vec![1i64; stride]; full + 1];
        let err = Plaintext::try_encode_strided(&cols, stride, &p).err().expect("must reject");
        assert_eq!(
            err,
            EncodingError::StrideOverrun {
                features: full + 1,
                stride,
                batch: stride,
                capacity: p.n
            }
        );
        let msg = err.to_string();
        assert!(msg.contains("overruns") && msg.contains(&p.n.to_string()), "{msg}");

        // one sample over the stride window: lanes would fold together
        let cols = vec![vec![1i64; stride + 1]; 2];
        let err = Plaintext::try_encode_strided(&cols, stride, &p).err().expect("must reject");
        assert!(matches!(err, EncodingError::StrideOverrun { batch, .. } if batch == stride + 1));

        // decode validates the same geometry
        let pt = Plaintext::encode_batch(&[1, 2], &p);
        assert!(pt.try_decode_strided(stride, full + 1, 1).is_err());
    }

    #[test]
    fn strided_encode_range_check_reports_flat_index() {
        let p = BgvParams::test_params();
        let half = (p.t / 2) as i64;
        let cols = vec![vec![0, 0], vec![0, half + 1]];
        let err = Plaintext::try_encode_strided(&cols, 4, &p).err().expect("must reject");
        assert_eq!(err, EncodingError::ValueOutOfRange { index: 3, value: half + 1, half });
    }

    #[test]
    fn cached_plaintext_matches_fresh_lift_at_every_level() {
        let ctx = BgvContext::new(BgvParams::test_params());
        let pt = Plaintext::encode_batch(&[5, -6, 7], &ctx.params);
        let cached = CachedPlaintext::new(pt.clone(), &ctx);
        for level in 1..=ctx.top_level() {
            let mut fresh = pt.to_rns(ctx.ctx_at(level), level);
            fresh.to_ntt();
            let c = cached.ntt_at(level);
            assert!(c.is_ntt);
            assert_eq!(c.level, level);
            for i in 0..level {
                assert_eq!(c.res[i], fresh.res[i], "level {level} limb {i}");
            }
        }
    }

    #[test]
    fn center_reduces_symmetrically() {
        assert_eq!(Plaintext::center(0, 256), 0);
        assert_eq!(Plaintext::center(255, 256), -1);
        assert_eq!(Plaintext::center(128, 256), 128);
        assert_eq!(Plaintext::center(129, 256), -127);
    }
}
