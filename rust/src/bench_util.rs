//! Hand-rolled bench harness (the vendored crate set has no criterion).
//!
//! Every `cargo bench` target is a `harness = false` binary that times its
//! workload with [`time_op`], prints a paper-style table to stdout and
//! appends it to `bench_out/<name>.md`. `GLYPH_BENCH_FULL=1` switches the
//! crypto profiles from test-scale to the production-shaped parameters
//! (slower, used for the recorded EXPERIMENTS.md numbers).

use std::time::Instant;

/// Time `f` over `iters` runs; returns seconds per run.
pub fn time_op<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters.max(1) as f64
}

/// Time a single run.
pub fn time_once<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// Whether full-profile benching was requested.
pub fn full_profile() -> bool {
    std::env::var("GLYPH_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Write a markdown report to `bench_out/<name>.md` (and echo to stdout).
pub fn report(name: &str, contents: &str) {
    println!("{contents}");
    let _ = std::fs::create_dir_all("bench_out");
    let path = format!("bench_out/{name}.md");
    if let Err(e) = std::fs::write(&path, contents) {
        eprintln!("warning: could not write {path}: {e}");
    } else {
        eprintln!("[wrote {path}]");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_op_is_positive() {
        let t = time_op(3, || { std::hint::black_box((0..1000).sum::<u64>()); });
        assert!(t > 0.0);
    }
}
