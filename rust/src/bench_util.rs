//! Hand-rolled bench harness (the vendored crate set has no criterion).
//!
//! Every `cargo bench` target is a `harness = false` binary that times its
//! workload with [`time_op`], prints a paper-style table to stdout and
//! appends it to `bench_out/<name>.md`. [`report_json`] additionally emits a
//! machine-readable `bench_out/BENCH_<name>.json` (op name, secs/op,
//! threads, profile) so the perf trajectory can be tracked across PRs.
//! `GLYPH_BENCH_FULL=1` switches the crypto profiles from test-scale to the
//! production-shaped parameters (slower, used for the recorded
//! EXPERIMENTS.md §Perf numbers).

use std::time::Instant;

/// Time `f` over `iters` runs; returns seconds per run.
pub fn time_op<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters.max(1) as f64
}

/// Time a single run.
pub fn time_once<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// Whether full-profile benching was requested.
pub fn full_profile() -> bool {
    std::env::var("GLYPH_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Write a markdown report to `bench_out/<name>.md` (and echo to stdout).
pub fn report(name: &str, contents: &str) {
    println!("{contents}");
    let _ = std::fs::create_dir_all("bench_out");
    let path = format!("bench_out/{name}.md");
    if let Err(e) = std::fs::write(&path, contents) {
        eprintln!("warning: could not write {path}: {e}");
    } else {
        eprintln!("[wrote {path}]");
    }
}

/// One machine-readable measurement for [`report_json`].
pub struct BenchRecord {
    /// Operation name, e.g. `"gate_bootstrap"`.
    pub op: String,
    /// Mean wall-clock seconds per operation.
    pub secs_per_op: f64,
    /// Concurrent executors used for this measurement (1 = sequential).
    pub threads: usize,
}

impl BenchRecord {
    pub fn new(op: &str, secs_per_op: f64, threads: usize) -> Self {
        BenchRecord { op: op.to_string(), secs_per_op, threads }
    }

    /// Throughput view of the record.
    pub fn ops_per_sec(&self) -> f64 {
        if self.secs_per_op > 0.0 {
            1.0 / self.secs_per_op
        } else {
            f64::INFINITY
        }
    }
}

/// Emit `bench_out/BENCH_<name>.json`: `{name, profile, threads_available,
/// ops: [{op, secs_per_op, ops_per_sec, threads}]}`. Hand-rolled JSON — the
/// vendored crate set has no serde; op names must not need escaping.
pub fn report_json(name: &str, records: &[BenchRecord]) {
    report_json_with_counters(name, records, &[]);
}

/// [`report_json`] plus a `counters` object of integer facts that are not
/// timings — e.g. the relinearizations-per-row accounting of
/// `benches/bgv_mac.rs`. Keys must not need JSON escaping.
pub fn report_json_with_counters(name: &str, records: &[BenchRecord], counters: &[(&str, u64)]) {
    let profile = if full_profile() { "full" } else { "test" };
    let avail = crate::coordinator::executor::max_threads();
    let mut json = String::new();
    json.push_str(&format!(
        "{{\n  \"name\": \"{name}\",\n  \"profile\": \"{profile}\",\n  \"threads_available\": {avail},\n  \"ops\": [\n"
    ));
    for (i, r) in records.iter().enumerate() {
        let sep = if i + 1 == records.len() { "" } else { "," };
        json.push_str(&format!(
            "    {{\"op\": \"{}\", \"secs_per_op\": {:.9}, \"ops_per_sec\": {:.3}, \"threads\": {}}}{sep}\n",
            r.op,
            r.secs_per_op,
            r.ops_per_sec(),
            r.threads
        ));
    }
    json.push_str("  ]");
    if !counters.is_empty() {
        json.push_str(",\n  \"counters\": {\n");
        for (i, (k, v)) in counters.iter().enumerate() {
            let sep = if i + 1 == counters.len() { "" } else { "," };
            json.push_str(&format!("    \"{k}\": {v}{sep}\n"));
        }
        json.push_str("  }");
    }
    json.push_str("\n}\n");
    let _ = std::fs::create_dir_all("bench_out");
    let path = format!("bench_out/BENCH_{name}.json");
    if let Err(e) = std::fs::write(&path, &json) {
        eprintln!("warning: could not write {path}: {e}");
    } else {
        eprintln!("[wrote {path}]");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_op_is_positive() {
        let t = time_op(3, || { std::hint::black_box((0..1000).sum::<u64>()); });
        assert!(t > 0.0);
    }

    #[test]
    fn bench_record_throughput() {
        let r = BenchRecord::new("gate_bootstrap", 0.25, 4);
        assert!((r.ops_per_sec() - 4.0).abs() < 1e-9);
        assert_eq!(r.threads, 4);
    }
}
