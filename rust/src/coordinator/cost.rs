//! The calibrated cost model that regenerates the paper's Tables 2–8.
//!
//! The paper reports mini-batch latency = Σ (op count × per-op latency),
//! with the batch amortized inside each op (60 slots there, up to N
//! coefficients here). We measure per-op latencies of *our* implementation
//! ([`OpLatencies::measure`]) and also carry the paper's own Table-1 /
//! §4.1 numbers ([`OpLatencies::paper`]) so every generated table can be
//! printed in both calibrations side by side — shape comparisons stay
//! honest even where absolute constants differ (DESIGN.md §5).
//!
//! Since the `Network`/`Plan` redesign, this module prices a
//! `scheduler::Plan` — [`price_plan`] multiplies each step's [`StepOps`]
//! by the per-op latencies. The paper tables are built by constructing
//! paper-convention plans ([`mlp_paper_plan`], [`cnn_paper_plan`], which
//! keep the paper's own row order, switch-column labels and op-counting
//! conventions) and pricing them; a plan compiled from a live network can
//! be priced by the very same function.

use super::scheduler::{Plan, PlanStep, StepOps, StepPhase, System};
use crate::bgv::lut::LookupTable;
use crate::nn::engine::{EngineProfile, GlyphEngine};
use crate::nn::tensor::PackOrder;
use crate::nn::{activation, EncTensor};
use std::time::Instant;

/// Per-op latencies in seconds.
#[derive(Clone, Copy, Debug)]
pub struct OpLatencies {
    pub mult_cc: f64,
    pub mult_cp: f64,
    pub add_cc: f64,
    /// One full 8-bit table lookup (FHESGD sigmoid).
    pub tlu: f64,
    /// One value through the TFHE ReLU (extraction PBS + Alg-1 gates).
    pub relu_value: f64,
    /// One value through the Figure-4 softmax unit.
    pub softmax_value: f64,
    /// BGV→TFHE per-ciphertext fixed cost (Δ map + extract + key switch),
    /// amortized per value.
    pub switch_b2t_value: f64,
    /// TFHE→BGV per-ciphertext cost (pack + raise), amortized per value.
    pub switch_t2b_value: f64,
    /// One bootstrapped TFHE gate. Used to price steps that carry raw gate
    /// counts instead of per-activation-value costs (the compiled
    /// FC-gradient requantization).
    pub gate_bootstrap: f64,
}

impl OpLatencies {
    /// The paper's own numbers (Table 1 + §4.1): the "paper-calibrated"
    /// mode used for side-by-side table reproduction.
    pub fn paper() -> Self {
        OpLatencies {
            mult_cc: 0.012,
            mult_cp: 0.001,
            add_cc: 0.002,
            tlu: 307.9,
            relu_value: 0.1,       // §4.1: "takes only 0.1 second"
            softmax_value: 3.3,    // §4.1: "from 307.9 seconds to only 3.3"
            switch_b2t_value: 0.0013, // FC1-forward +0.96% over 1357s / 100K values
            switch_t2b_value: 0.0013,
            gate_bootstrap: 0.012, // §4.1 ReLU: ≈0.1 s / (7 gates + extraction)
        }
    }

    /// Measure this implementation. `test_scale` uses the reduced profiles
    /// (CI); production tables use the default profiles.
    pub fn measure(test_scale: bool) -> Self {
        let profile = if test_scale { EngineProfile::Test } else { EngineProfile::Default };
        let batch = if test_scale { 4 } else { 60 };
        let (engine, mut client) = GlyphEngine::setup(profile, batch, 20260710);

        // MultCC / MultCP / AddCC on realistic operands. MultCP is timed on
        // the cached evaluation-form path — the one the layers actually run
        // since the weight-cache redesign (pointwise only, no per-call NTT).
        let fhe = engine.fhe();
        let w = client.encrypt_scalar(9);
        let x = client.encrypt_batch(&vec![17; batch], 0);
        let wp = crate::bgv::CachedPlaintext::scalar(9, &fhe.ctx);
        let iters = if test_scale { 20 } else { 50 };
        let t0 = Instant::now();
        for _ in 0..iters {
            let mut t = w.fhe().clone();
            t.mul_assign(x.fhe(), &fhe.rlk, &fhe.ctx);
        }
        let mult_cc = t0.elapsed().as_secs_f64() / iters as f64;

        let t0 = Instant::now();
        for _ in 0..iters {
            let mut t = x.fhe().clone();
            t.mul_plain_cached_assign(&wp);
        }
        let mult_cp = t0.elapsed().as_secs_f64() / iters as f64;

        let t0 = Instant::now();
        for _ in 0..(iters * 10) {
            let mut t = x.fhe().clone();
            t.add_assign(w.fhe());
        }
        let add_cc = t0.elapsed().as_secs_f64() / (iters * 10) as f64;

        // ReLU per value: run one ciphertext through the full pipeline.
        let u = EncTensor::new(vec![client.encrypt_batch(&vec![33; batch], 0)], vec![1], PackOrder::Forward, 0);
        let t0 = Instant::now();
        let (_a, _st) = activation::relu_layer(&engine, &u, 0, PackOrder::Forward);
        let relu_total = t0.elapsed().as_secs_f64();
        let relu_value = relu_total / batch as f64;

        // Switch costs per value: extraction only (Δ + extract + ksk).
        let positions: Vec<usize> = (0..batch).collect();
        let t0 = Instant::now();
        let _l = fhe.fwd_switch.to_torus_lanes(u.cts[0].fhe(), batch).expect("lanes fit the ring");
        let switch_b2t_value = t0.elapsed().as_secs_f64() / batch as f64;
        let lwes: Vec<crate::tfhe::LweCiphertext> = (0..batch)
            .map(|i| crate::tfhe::LweCiphertext::trivial((i as u32) << 24, engine.gate_ext_dim()))
            .collect();
        let t0 = Instant::now();
        let _p = fhe.bwd_switch.pack_at_and_raise(&lwes, &positions, &fhe.auth);
        let switch_t2b_value = t0.elapsed().as_secs_f64() / batch as f64;

        // Softmax per value (Figure-4 MUX tree at the configured width; use
        // 4 bits in test scale to keep CI fast, 8 in production).
        let sm_bits = if test_scale { 3 } else { 8 };
        let unit = activation::SoftmaxUnit::logistic(sm_bits, 4);
        let bits = engine.switch_to_bits(&u.cts[0], &[0], 0);
        let t0 = Instant::now();
        let _o = unit.evaluate_mux(&engine, &bits[0][..sm_bits]);
        let softmax_value = t0.elapsed().as_secs_f64();

        // Gate bootstrap: one AND on the gate cloud key.
        let tt = crate::tfhe::LweCiphertext::trivial(
            crate::tfhe::encode_bit(true),
            fhe.gate_ck.params.n,
        );
        let gate_iters = if test_scale { 4 } else { 10 };
        let t0 = Instant::now();
        for _ in 0..gate_iters {
            let _ = fhe.gate_ck.and(&tt, &tt);
        }
        let gate_bootstrap = t0.elapsed().as_secs_f64() / gate_iters as f64;

        // TLU: one real bit-sliced lookup in the t=2 profile.
        let tlu_domain = crate::train::fhesgd::TluDomain::new(test_scale, 7);
        let tlu_bits = if test_scale { 4 } else { 8 };
        let table = LookupTable::sigmoid(tlu_bits, 2, (tlu_bits - 1) as u32);
        let enc_bits = tlu_domain.encrypt_bits(5, tlu_bits);
        let t0 = Instant::now();
        let (_out, _c) = table.evaluate(&enc_bits, &tlu_domain.rlk, &tlu_domain.ctx);
        let tlu = t0.elapsed().as_secs_f64();

        OpLatencies {
            mult_cc,
            mult_cp,
            add_cc,
            tlu,
            relu_value,
            softmax_value,
            switch_b2t_value,
            switch_t2b_value,
            gate_bootstrap,
        }
    }
}

/// One row of a paper-style mini-batch table.
#[derive(Clone, Debug, Default)]
pub struct TableRow {
    pub layer: String,
    pub time_s: f64,
    pub mult_cp: u64,
    pub mult_cc: u64,
    pub add_cc: u64,
    pub tlu: u64,
    pub act: u64,
    pub switch: &'static str,
}

impl TableRow {
    pub fn hop(&self) -> u64 {
        self.mult_cp + self.mult_cc + self.add_cc + self.tlu + self.act
    }
}

/// Which training scheme a table models.
#[derive(Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    Fhesgd,
    GlyphMlp,
}

/// Price one plan step: Σ (op count × per-op latency), with the paper's
/// +0.96% Δ/extract overhead applied to switch-producing FC rows.
///
/// Activation steps are priced per value (`relu_value`/`softmax_value`
/// already amortize their gates, extraction and switch round trip — the
/// paper's convention). Steps with *no* per-value activation count but raw
/// gate/switch ops — the compiled FC-gradient requantization — are priced
/// from those counts directly, so compiled `Network` plans lose nothing.
pub fn price_step(step: &PlanStep, lat: &OpLatencies) -> TableRow {
    let o = &step.ops;
    let mut time = o.mult_cc as f64 * lat.mult_cc
        + o.mult_cp as f64 * lat.mult_cp
        + o.add_cc as f64 * lat.add_cc
        + o.tlu as f64 * lat.tlu
        + o.relu_values as f64 * (lat.relu_value + lat.switch_b2t_value + lat.switch_t2b_value)
        + o.softmax_values as f64
            * (lat.softmax_value + lat.switch_b2t_value + lat.switch_t2b_value);
    if o.act_values() == 0 {
        // not covered by a per-value activation latency: price the raw
        // gate bootstraps and per-ciphertext switches (each B2T here
        // extracts a single value, so the per-value switch cost applies)
        time += o.act_gates as f64 * lat.gate_bootstrap
            + o.switch_b2t as f64 * lat.switch_b2t_value
            + o.switch_t2b as f64 * lat.switch_t2b_value;
    }
    if step.fc_switch_overhead {
        time *= 1.0096;
    }
    TableRow {
        layer: step.name.clone(),
        time_s: time,
        mult_cp: o.mult_cp,
        mult_cc: o.mult_cc,
        add_cc: o.add_cc,
        tlu: o.tlu,
        act: o.act_values(),
        switch: step.switch,
    }
}

/// Price every step of a plan — the one pricing path shared by the paper
/// tables and compiled `Network` plans.
pub fn price_plan(plan: &Plan, lat: &OpLatencies) -> Vec<TableRow> {
    plan.steps.iter().map(|s| price_step(s, lat)).collect()
}

/// The paper-convention MLP plan behind Tables 2/3/6/7: the paper's own row
/// order, switch labels and op-counting (AddCC = MAC count, act values per
/// neuron with the batch amortized inside the op).
pub fn mlp_paper_plan(dims: &[usize], scheme: Scheme) -> Plan {
    let l = dims.len() - 1; // number of FC layers
    let mut steps = Vec::new();
    let fc_macs = |i: usize| (dims[i] * dims[i + 1]) as u64;

    let fc_step = |name: String, phase: StepPhase, macs: u64, switch: &'static str| PlanStep {
        name,
        unit: None,
        phase,
        system: System::Bgv,
        switch,
        ops: StepOps { mult_cc: macs, add_cc: macs, ..Default::default() },
        // the Δ/extract part of the switch rides on the FC output
        // (paper: +0.96% on FC1-forward)
        fc_switch_overhead: switch != "-",
    };
    let act_step = |name: String, phase: StepPhase, neurons: u64, last: bool| match scheme {
        Scheme::Fhesgd => PlanStep {
            name,
            unit: None,
            phase,
            system: System::Bgv,
            switch: "-",
            ops: StepOps { tlu: neurons, ..Default::default() },
            fc_switch_overhead: false,
        },
        Scheme::GlyphMlp => PlanStep {
            name,
            unit: None,
            phase,
            system: System::Tfhe,
            switch: "TFHE-BGV",
            ops: StepOps {
                relu_values: if last { 0 } else { neurons },
                softmax_values: if last { neurons } else { 0 },
                ..Default::default()
            },
            fc_switch_overhead: false,
        },
    };
    let sw = |on: bool| if on { "BGV-TFHE" } else { "-" };

    // forward
    for i in 0..l {
        steps.push(fc_step(
            format!("FC{}-forward", i + 1),
            StepPhase::Forward,
            fc_macs(i),
            sw(scheme == Scheme::GlyphMlp),
        ));
        steps.push(act_step(
            format!("Act{}-forward", i + 1),
            StepPhase::Forward,
            dims[i + 1] as u64,
            i == l - 1,
        ));
    }
    // backward
    steps.push(PlanStep {
        name: format!("Act{l}-error"),
        unit: None,
        phase: StepPhase::Error,
        system: System::Bgv,
        switch: "-",
        ops: StepOps { add_cc: dims[l] as u64, ..Default::default() },
        fc_switch_overhead: false,
    });
    for i in (0..l).rev() {
        if i > 0 {
            steps.push(fc_step(format!("FC{}-error", i + 1), StepPhase::Error, fc_macs(i), "-"));
        }
        steps.push(fc_step(
            format!("FC{}-gradient", i + 1),
            StepPhase::Gradient,
            fc_macs(i),
            sw(scheme == Scheme::GlyphMlp),
        ));
        if i > 0 {
            steps.push(act_step(
                format!("Act{i}-error"),
                StepPhase::Error,
                dims[i] as u64,
                false,
            ));
        }
    }
    Plan { steps }
}

/// Generate the FHESGD (Table 2/6) or Glyph (Table 3/7) MLP mini-batch
/// breakdown for `dims` (e.g. [784,128,32,10]).
pub fn mlp_table(dims: &[usize], scheme: Scheme, lat: &OpLatencies) -> Vec<TableRow> {
    price_plan(&mlp_paper_plan(dims, scheme), lat)
}

/// CNN shape description for the Table 4/8 generator (paper counting:
/// conv ops = out_ch · oh · ow · k²; see DESIGN.md §5 on the per-channel
/// convention).
pub struct CnnShape {
    pub conv1: (u64, u64, u64), // (values = oc·oh·ow, k2, _)
    pub conv2: (u64, u64, u64),
    /// Activation-layer value counts (the paper's Act columns; for the
    /// Cancer tables these follow the paper's own Table-8 rows).
    pub act1: u64,
    pub act2: u64,
    pub pool1_out: u64,
    pub pool2_out: u64,
    pub fc1: (u64, u64), // in, out
    pub fc2: (u64, u64),
    pub classes: u64,
}

impl CnnShape {
    pub fn paper_mnist() -> Self {
        CnnShape {
            conv1: (6 * 26 * 26, 9, 0),
            conv2: (16 * 11 * 11, 9, 0),
            act1: 6 * 26 * 26,  // paper: 4.1K
            act2: 16 * 11 * 11, // paper: 1.9K
            pool1_out: 6 * 13 * 13,
            pool2_out: 16 * 5 * 5,
            fc1: (400, 84),
            fc2: (84, 10),
            classes: 10,
        }
    }

    pub fn paper_cancer() -> Self {
        // Row counts follow the paper's own Table 8 (notably FC1 = 51K MACs,
        // i.e. a 400-wide feature input, and per-output-channel conv
        // counting — see DESIGN.md §5 on the paper's conv conventions).
        CnnShape {
            conv1: (64 * 26 * 26, 9, 0),
            conv2: (96 * 11 * 11, 9, 0),
            act1: 10_800, // paper Table 8 Act1-forward
            act2: 11_616, // 96·11² (paper lists 29K; see DESIGN.md §5)
            pool1_out: 64 * 13 * 13,
            pool2_out: 96 * 5 * 5,
            fc1: (400, 128),
            fc2: (128, 7),
            classes: 7,
        }
    }
}

/// The paper-convention transfer-learning CNN plan behind Tables 4/8
/// (frozen plaintext features, trainable FC head; paper row order and
/// switch labels preserved).
pub fn cnn_paper_plan(s: &CnnShape) -> Plan {
    let mut steps = Vec::new();
    let plain_step = |name: &str, phase: StepPhase, count: u64, switch: &'static str| PlanStep {
        name: name.into(),
        unit: None,
        phase,
        system: System::Bgv,
        switch,
        ops: StepOps { mult_cp: count, add_cc: count, ..Default::default() },
        fc_switch_overhead: false,
    };
    let act_step = |name: &str, phase: StepPhase, values: u64, softmax: bool| PlanStep {
        name: name.into(),
        unit: None,
        phase,
        system: System::Tfhe,
        switch: "TFHE-BGV",
        ops: StepOps {
            relu_values: if softmax { 0 } else { values },
            softmax_values: if softmax { values } else { 0 },
            ..Default::default()
        },
        fc_switch_overhead: false,
    };
    let fc_step = |name: &str, phase: StepPhase, macs: u64, switch: &'static str| PlanStep {
        name: name.into(),
        unit: None,
        phase,
        system: System::Bgv,
        switch,
        ops: StepOps { mult_cc: macs, add_cc: macs, ..Default::default() },
        // paper convention: every head FC row carries the switch overhead
        fc_switch_overhead: true,
    };

    use StepPhase::{Error, Forward, Gradient};
    steps.push(plain_step("Conv1-forward", Forward, s.conv1.0 * s.conv1.1, "-"));
    steps.push(plain_step("BN1-forward", Forward, s.conv1.0 * 2, "BGV-TFHE"));
    steps.push(act_step("Act1-forward", Forward, s.act1, false));
    steps.push(plain_step("Pool1-forward", Forward, s.pool1_out * 4, "-"));
    steps.push(plain_step("Conv2-forward", Forward, s.conv2.0 * s.conv2.1, "-"));
    steps.push(plain_step("BN2-forward", Forward, s.conv2.0 * 2, "BGV-TFHE"));
    steps.push(act_step("Act2-forward", Forward, s.act2, false));
    steps.push(plain_step("Pool2-forward", Forward, s.pool2_out * 4, "-"));
    steps.push(fc_step("FC1-forward", Forward, s.fc1.0 * s.fc1.1, "BGV-TFHE"));
    steps.push(act_step("Act3-forward", Forward, s.fc1.1, false));
    steps.push(fc_step("FC2-forward", Forward, s.fc2.0 * s.fc2.1, "BGV-TFHE"));
    steps.push(act_step("Act4-forward", Forward, s.classes, true));
    steps.push(PlanStep {
        name: "Act4-error".into(),
        unit: None,
        phase: Error,
        system: System::Bgv,
        switch: "-",
        ops: StepOps { add_cc: s.classes, ..Default::default() },
        fc_switch_overhead: false,
    });
    steps.push(fc_step("FC2-error", Error, s.fc2.0 * s.fc2.1, "-"));
    steps.push(fc_step("FC2-gradient", Gradient, s.fc2.0 * s.fc2.1, "BGV-TFHE"));
    steps.push(act_step("Act3-error", Error, s.fc1.1, false));
    steps.push(fc_step("FC1-gradient", Gradient, s.fc1.0 * s.fc1.1, "-"));
    Plan { steps }
}

/// Generate the Glyph CNN + transfer-learning breakdown (Tables 4/8).
pub fn cnn_table(s: &CnnShape, lat: &OpLatencies) -> Vec<TableRow> {
    price_plan(&cnn_paper_plan(s), lat)
}

/// Sum a table into a Total row.
pub fn total_row(rows: &[TableRow]) -> TableRow {
    let mut t = TableRow { layer: "Total".into(), switch: "-", ..Default::default() };
    for r in rows {
        t.time_s += r.time_s;
        t.mult_cp += r.mult_cp;
        t.mult_cc += r.mult_cc;
        t.add_cc += r.add_cc;
        t.tlu += r.tlu;
        t.act += r.act;
    }
    t
}

/// Render rows as a markdown table (what the benches write to bench_out/).
pub fn to_markdown(title: &str, rows: &[TableRow]) -> String {
    let mut s = format!("### {title}\n\n| Layer | Time(s) | HOP | MultCP | MultCC | AddCC | TLU | Act | Switch |\n|---|---|---|---|---|---|---|---|---|\n");
    let mut all = rows.to_vec();
    all.push(total_row(rows));
    for r in &all {
        s.push_str(&format!(
            "| {} | {:.4} | {} | {} | {} | {} | {} | {} | {} |\n",
            r.layer,
            r.time_s,
            r.hop(),
            r.mult_cp,
            r.mult_cc,
            r.add_cc,
            r.tlu,
            r.act,
            r.switch
        ));
    }
    s
}

/// Overall-training estimator (Table 5 methodology: mini-batch latency ×
/// mini-batches × epochs, with measured thread-scaling efficiency).
pub fn overall_latency(minibatch_s: f64, batches_per_epoch: u64, epochs: u64, speedup: f64) -> f64 {
    minibatch_s * batches_per_epoch as f64 * epochs as f64 / speedup
}

/// Measure the thread-scaling speedup of a bundle of independent MACs
/// (Table 5's parallel SGD argument) — through the scratch-backed MAC
/// engine, i.e. the path SGD actually runs since the lazy-relin redesign.
pub fn measure_scaling(threads: usize, work_items: usize) -> f64 {
    use crate::coordinator::executor::GlyphPool;
    use crate::nn::backend::{Ct, Term};
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 4, 777);
    let ws: Vec<Ct> = (0..work_items).map(|i| client.encrypt_scalar(i as i64 % 100)).collect();
    let xs: Vec<Ct> = (0..work_items).map(|_| client.encrypt_batch(&[1, 2, 3, 4], 0)).collect();
    let rows: Vec<Vec<Term>> =
        (0..work_items).map(|i| vec![Term::Cc(&ws[i], &xs[i])]).collect();
    let t0 = Instant::now();
    let _r = engine.mac_rows_limit(&rows, 1);
    let t1 = t0.elapsed().as_secs_f64();
    // honor widths beyond the resident pool via a one-off pool (Table 5
    // sweeps past the machine's core count) — spawned OUTSIDE the timed
    // region so thread startup/join does not deflate the speedup
    let wide_pool =
        if threads > GlyphPool::global().threads() { Some(GlyphPool::new(threads)) } else { None };
    let t0 = Instant::now();
    let _r = match &wide_pool {
        Some(pool) => engine.mac_rows_on(pool, &rows),
        None => engine.mac_rows_limit(&rows, threads),
    };
    let tn = t0.elapsed().as_secs_f64();
    t1 / tn
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibrated_fhesgd_table_reproduces_headlines() {
        // Using the paper's own per-op latencies, the generated Table 2 must
        // show ≈118K s total with activations ≥ 97% of the time.
        let lat = OpLatencies::paper();
        let rows = mlp_table(&[784, 128, 32, 10], Scheme::Fhesgd, &lat);
        let total = total_row(&rows);
        // paper reports 118K s; its own per-row numbers imply ≈350 s/TLU vs
        // Table 1's 307.9 s — with the Table-1 figure the total is ≈105K.
        assert!((95_000.0..130_000.0).contains(&total.time_s), "total {}", total.time_s);
        let act_time: f64 = rows.iter().filter(|r| r.layer.starts_with("Act")).map(|r| r.time_s).sum();
        assert!(act_time / total.time_s > 0.95, "act share {}", act_time / total.time_s);
        assert_eq!(total.tlu, 330);
        // paper reports ≈213K MultCC; exact count from the layer dims:
        // fwd 3 FCs + FC2/FC3 errors + 3 gradients = 213,952
        assert_eq!(total.mult_cc, 213_952);
    }

    #[test]
    fn paper_calibrated_glyph_table_reduces_latency_97pct() {
        let lat = OpLatencies::paper();
        let fhesgd = total_row(&mlp_table(&[784, 128, 32, 10], Scheme::Fhesgd, &lat));
        let glyph = total_row(&mlp_table(&[784, 128, 32, 10], Scheme::GlyphMlp, &lat));
        let reduction = 1.0 - glyph.time_s / fhesgd.time_s;
        assert!(reduction > 0.95, "reduction {reduction}");
        // the paper's Table-3 total is 2991 s
        assert!((glyph.time_s - 2991.0).abs() / 2991.0 < 0.5, "glyph total {}", glyph.time_s);
    }

    #[test]
    fn cnn_transfer_reduces_vs_glyph_mlp() {
        let lat = OpLatencies::paper();
        let mlp = total_row(&mlp_table(&[784, 128, 32, 10], Scheme::GlyphMlp, &lat));
        let cnn = total_row(&cnn_table(&CnnShape::paper_mnist(), &lat));
        assert!(cnn.time_s < mlp.time_s, "cnn {} vs mlp {}", cnn.time_s, mlp.time_s);
        // FC rows: FC1-forward, FC1-gradient (2×400·84) + FC2-forward/-error/-gradient (3×84·10)
        assert_eq!(cnn.mult_cc, 2 * 400 * 84 + 3 * 84 * 10);
        assert!(cnn.mult_cp > 0);
    }

    #[test]
    fn markdown_renders() {
        let lat = OpLatencies::paper();
        let rows = mlp_table(&[4, 3, 2], Scheme::GlyphMlp, &lat);
        let md = to_markdown("test", &rows);
        assert!(md.contains("FC1-forward"));
        assert!(md.contains("Total"));
    }

    #[test]
    fn overall_estimator() {
        // paper: 2991 s × 1000 batches × 50 epochs ≈ 4.74 years single-thread
        let secs = overall_latency(2991.0, 1000, 50, 1.0);
        assert!((secs / (365.25 * 86400.0) - 4.74).abs() < 0.1);
    }
}
