//! `GlyphPool`: a persistent channel-based worker pool for independent
//! homomorphic work items.
//!
//! SGD's per-neuron MACs and per-value activations are embarrassingly
//! parallel (the paper's §6.3: "the weight updates in SGD are independent");
//! Table 5's 1→48-thread scaling sweep runs through this executor. The old
//! implementation spawned fresh OS threads per call and took two mutex
//! locks per item; this one keeps the threads alive across calls, hands out
//! items with a single atomic fetch-add, and — crucially for the PBS and
//! BGV MAC hot paths — owns one [`WorkerScratch`] (PBS buffers + BGV MAC
//! accumulators) per worker, so a batched bootstrap or MAC fan-out reuses
//! warm buffers instead of re-allocating per ciphertext
//! (EXPERIMENTS.md §Perf).
//!
//! Work submission is scoped: `map*` borrows its items and closure, blocks
//! until every executor has finished, and propagates the first panic. Type
//! erasure goes through a monomorphized `unsafe fn` + shared-state pointer
//! (the standard scoped-pool technique), so non-`'static` borrows are fine.

use crate::bgv::BgvScratch;
use crate::switch::SwitchScratch;
use crate::tfhe::scratch::PbsScratch;
use std::cell::{Cell, UnsafeCell};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Per-worker scratch bundle: the TFHE PBS buffers, the BGV MAC
/// accumulators *and* the scheme-switch workspaces — one of each per pool
/// worker, so all three hot paths (blind rotations, lazy-relin MAC rows,
/// lane extraction / repacking) reuse warm buffers across batched fan-outs.
pub struct WorkerScratch {
    pub pbs: PbsScratch,
    pub bgv: BgvScratch,
    pub switch: SwitchScratch,
}

impl WorkerScratch {
    pub fn new() -> Self {
        WorkerScratch { pbs: PbsScratch::new(), bgv: BgvScratch::new(), switch: SwitchScratch::new() }
    }
}

impl Default for WorkerScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// One queued unit of execution: the address of the scoped shared state
/// (as a `usize`, so the job is trivially `Send`; validity is guaranteed by
/// the submitter blocking until every executor signals completion) plus the
/// monomorphized entry that knows its concrete type.
struct RawJob {
    data: usize,
    call: unsafe fn(usize, &mut WorkerScratch),
}

thread_local! {
    static IS_POOL_WORKER: Cell<bool> = Cell::new(false);
}

fn is_pool_worker() -> bool {
    IS_POOL_WORKER.with(|f| f.get())
}

/// Scoped state shared between the submitting thread and the executors of
/// one `map*` call.
struct MapShared<T, R, F> {
    f: F,
    items: Vec<UnsafeCell<Option<T>>>,
    out: Vec<UnsafeCell<Option<R>>>,
    next: AtomicUsize,
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
    executors_left: Mutex<usize>,
    done: Condvar,
}

// SAFETY: slots are only touched by the executor that claimed their index
// via the `next` fetch-add, so access is disjoint; `f` is only shared.
unsafe impl<T: Send, R: Send, F: Sync> Sync for MapShared<T, R, F> {}

impl<T, R, F> MapShared<T, R, F>
where
    T: Send,
    R: Send,
    F: Fn(T, &mut WorkerScratch) -> R + Sync,
{
    /// Executor body: claim items until the queue is drained (or aborted by
    /// a panic), then signal completion. The *last* touch of `self` is the
    /// completion signal, which the submitter blocks on — that ordering is
    /// what makes the scoped borrow sound.
    fn run(&self, scratch: &mut WorkerScratch) {
        let n = self.items.len();
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            // SAFETY: index `i` was claimed exactly once (atomic fetch-add).
            let item = unsafe { (*self.items[i].get()).take().expect("item claimed once") };
            match catch_unwind(AssertUnwindSafe(|| (self.f)(item, scratch))) {
                Ok(r) => unsafe {
                    *self.out[i].get() = Some(r);
                },
                Err(payload) => {
                    let mut slot = self.panic.lock().expect("panic slot");
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                    drop(slot);
                    // Abort the remaining queue: park `next` at the end
                    // (monotonic, so no wrap-around from racing fetch-adds).
                    self.next.store(n, Ordering::Relaxed);
                }
            }
        }
        let mut left = self.executors_left.lock().expect("executor count");
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }
}

unsafe fn run_erased<T, R, F>(data: usize, scratch: &mut WorkerScratch)
where
    T: Send,
    R: Send,
    F: Fn(T, &mut WorkerScratch) -> R + Sync,
{
    let shared = &*(data as *const MapShared<T, R, F>);
    shared.run(scratch);
}

/// Persistent worker pool; one [`WorkerScratch`] per worker.
pub struct GlyphPool {
    tx: Mutex<Option<Sender<RawJob>>>,
    threads: usize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl GlyphPool {
    /// Spawn a pool with `threads` workers (at least 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = channel::<RawJob>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|w| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("glyph-worker-{w}"))
                    .spawn(move || worker_loop(&rx))
                    .expect("spawn pool worker")
            })
            .collect();
        GlyphPool { tx: Mutex::new(Some(tx)), threads, handles: Mutex::new(handles) }
    }

    /// The process-wide pool: `GLYPH_THREADS` workers if set, otherwise the
    /// available hardware parallelism (minimum 4, so small machines still
    /// exercise concurrency). Built on first use, lives for the process.
    pub fn global() -> &'static GlyphPool {
        static POOL: OnceLock<GlyphPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let threads = std::env::var("GLYPH_THREADS")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&t| t >= 1)
                .unwrap_or_else(|| max_threads().max(4));
            GlyphPool::new(threads)
        })
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Order-preserving parallel map with per-worker scratch; at most
    /// `limit` concurrent executors. Runs inline (with a private scratch)
    /// when the limit or item count makes parallelism pointless, or when
    /// called from inside a pool worker (nested fan-out must not deadlock
    /// the pool against itself).
    pub fn map_limit_with<T, R, F>(&self, items: Vec<T>, limit: usize, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T, &mut WorkerScratch) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let limit = limit.min(self.threads).min(n);
        if limit <= 1 || is_pool_worker() {
            let mut scratch = WorkerScratch::new();
            return items.into_iter().map(|t| f(t, &mut scratch)).collect();
        }
        let shared = MapShared {
            f,
            items: items.into_iter().map(|t| UnsafeCell::new(Some(t))).collect(),
            out: (0..n).map(|_| UnsafeCell::new(None)).collect(),
            next: AtomicUsize::new(0),
            panic: Mutex::new(None),
            executors_left: Mutex::new(limit),
            done: Condvar::new(),
        };
        {
            let data = &shared as *const MapShared<T, R, F> as usize;
            let guard = self.tx.lock().expect("pool sender");
            let tx = guard.as_ref().expect("pool is shut down");
            for _ in 0..limit {
                tx.send(RawJob { data, call: run_erased::<T, R, F> }).expect("pool workers alive");
            }
        }
        // Block until every executor instance has signalled; only then may
        // `shared` (and the borrows inside `f`) go out of scope.
        let mut left = shared.executors_left.lock().expect("executor count");
        while *left > 0 {
            left = shared.done.wait(left).expect("condvar wait");
        }
        drop(left);
        if let Some(payload) = shared.panic.lock().expect("panic slot").take() {
            resume_unwind(payload);
        }
        shared
            .out
            .into_iter()
            .map(|slot| slot.into_inner().expect("every slot filled"))
            .collect()
    }

    /// Order-preserving parallel map with per-worker scratch across all
    /// workers.
    pub fn map_with<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T, &mut WorkerScratch) -> R + Sync,
    {
        self.map_limit_with(items, usize::MAX, f)
    }

    /// Order-preserving parallel map (no scratch access).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        self.map_limit_with(items, usize::MAX, move |t, _scratch| f(t))
    }
}

impl Drop for GlyphPool {
    fn drop(&mut self) {
        // Disconnect the channel so workers drain and exit, then join them.
        if let Ok(mut tx) = self.tx.lock() {
            *tx = None;
        }
        if let Ok(mut handles) = self.handles.lock() {
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

fn worker_loop(rx: &Mutex<Receiver<RawJob>>) {
    IS_POOL_WORKER.with(|f| f.set(true));
    let mut scratch = WorkerScratch::new();
    loop {
        let job = {
            let guard = rx.lock().expect("pool receiver");
            guard.recv()
        };
        match job {
            // SAFETY: contract of `RawJob` — the shared state is alive
            // until its submitter observes the completion signal `run`
            // sends after its last access.
            Ok(job) => unsafe { (job.call)(job.data, &mut scratch) },
            Err(_) => break, // channel closed: pool dropped
        }
    }
}

/// Map `f` over `items` preserving order with exactly `threads` concurrent
/// executors. Compatibility wrapper for the original spawn-per-call
/// executor; `threads <= 1` runs inline. Requests wider than the resident
/// pool (Table 5's thread-scaling sweep) honor the exact width via a
/// one-off pool instead of silently clamping the measurement.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let global = GlyphPool::global();
    if threads > global.threads() && threads > 1 && items.len() > 1 {
        let pool = GlyphPool::new(threads);
        return pool.map_limit_with(items, threads, move |t, _scratch| f(t));
    }
    global.map_limit_with(items, threads, move |t, _scratch| f(t))
}

/// Available hardware parallelism.
pub fn max_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        for threads in [1, 2, 8] {
            let out = parallel_map(items.clone(), threads, |x| x * x);
            assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn parallel_map_actually_uses_threads() {
        use std::collections::HashSet;
        let seen = Mutex::new(HashSet::new());
        let _ = parallel_map((0..64).collect::<Vec<_>>(), 4, |x| {
            // make items slow enough that one thread cannot drain the queue
            std::thread::sleep(std::time::Duration::from_millis(2));
            seen.lock().unwrap().insert(std::thread::current().id());
            x
        });
        assert!(seen.lock().unwrap().len() > 1);
    }

    #[test]
    fn pool_is_reused_across_calls() {
        use std::collections::HashSet;
        let pool = GlyphPool::new(3);
        let mut all_ids = HashSet::new();
        for round in 0..4 {
            let ids = Mutex::new(HashSet::new());
            let out = pool.map((0..32u64).collect(), |x| {
                std::thread::sleep(std::time::Duration::from_millis(1));
                ids.lock().unwrap().insert(std::thread::current().id());
                x + round
            });
            assert_eq!(out, (0..32u64).map(|x| x + round).collect::<Vec<_>>());
            all_ids.extend(ids.into_inner().unwrap());
        }
        // persistent workers: across 4 calls we still only ever saw the
        // pool's threads (plus possibly fewer on a slow machine), never a
        // fresh set per call.
        assert!(all_ids.len() <= 3, "saw {} distinct workers from a 3-thread pool", all_ids.len());
    }

    #[test]
    fn map_with_hands_each_worker_a_scratch() {
        let pool = GlyphPool::new(2);
        // size the scratch inside the job; the call must succeed and return
        // in order — and the scratch must be a real per-worker buffer.
        let out = pool.map_with((0..8usize).collect(), |i, scratch| {
            let ring = scratch.pbs.ring(64);
            ring.dig[0] = i as i32;
            (i, ring.n)
        });
        assert_eq!(out, (0..8usize).map(|i| (i, 64)).collect::<Vec<_>>());
    }

    #[test]
    fn borrowed_items_and_closures_work() {
        // the scoped design must accept non-'static borrows
        let data: Vec<String> = (0..16).map(|i| format!("item-{i}")).collect();
        let refs: Vec<&String> = data.iter().collect();
        let lens = GlyphPool::global().map(refs, |s| s.len());
        assert_eq!(lens, data.iter().map(|s| s.len()).collect::<Vec<_>>());
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = GlyphPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.map((0..16i32).collect(), |x| {
                if x == 7 {
                    panic!("boom at {x}");
                }
                x
            })
        }));
        assert!(result.is_err(), "panic inside a work item must propagate to the caller");
        // the pool must still execute subsequent work
        let out = pool.map((0..4i32).collect(), |x| x * 2);
        assert_eq!(out, vec![0, 2, 4, 6]);
    }

    #[test]
    fn nested_fan_out_runs_inline_instead_of_deadlocking() {
        let pool = GlyphPool::global();
        let out = pool.map((0..4u32).collect(), |outer| {
            // a nested map from inside a worker must not wait on the pool
            let inner = GlyphPool::global().map((0..4u32).collect(), move |i| i + outer);
            inner.into_iter().sum::<u32>()
        });
        assert_eq!(out, vec![6, 10, 14, 18]);
    }

    #[test]
    fn empty_and_single_item_edge_cases() {
        let pool = GlyphPool::new(2);
        let empty: Vec<u8> = pool.map(Vec::new(), |x: u8| x);
        assert!(empty.is_empty());
        assert_eq!(pool.map(vec![41u8], |x| x + 1), vec![42]);
    }
}
