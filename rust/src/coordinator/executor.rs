//! Thread-pool execution of independent homomorphic work items.
//!
//! SGD's per-neuron MACs and per-value activations are embarrassingly
//! parallel (the paper's §6.3: "the weight updates in SGD are independent");
//! Table 5's 1→48-thread scaling sweep runs through this executor. Plain
//! `std::thread::scope` — the vendored crate set has no rayon, and the work
//! items are large enough that a work-stealing pool would not matter.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `items` using `threads` OS threads; preserves order.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let items: Vec<std::sync::Mutex<Option<T>>> =
        items.into_iter().map(|t| std::sync::Mutex::new(Some(t))).collect();
    let slots: Vec<std::sync::Mutex<Option<R>>> = (0..n).map(|_| std::sync::Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n) {
            let items = &items;
            let slots = &slots;
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = items[i].lock().unwrap().take().unwrap();
                let r = f(item);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots.into_iter().map(|m| m.into_inner().unwrap().unwrap()).collect()
}

/// Available hardware parallelism.
pub fn max_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        for threads in [1, 2, 8] {
            let out = parallel_map(items.clone(), threads, |x| x * x);
            assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn parallel_map_actually_uses_threads() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let seen = Mutex::new(HashSet::new());
        let _ = parallel_map((0..64).collect::<Vec<_>>(), 4, |x| {
            // make items slow enough that one thread cannot drain the queue
            std::thread::sleep(std::time::Duration::from_millis(2));
            seen.lock().unwrap().insert(std::thread::current().id());
            x
        });
        assert!(seen.lock().unwrap().len() > 1);
    }
}
