//! Homomorphic-operation accounting — the HOP / MultCC / MultCP / AddCC /
//! TLU / Act / Switch columns of the paper's Tables 2–4 and 6–8.

use std::sync::atomic::{AtomicU64, Ordering};

/// Thread-safe operation counters. One per engine; layers and training
/// loops record into it, the cost model and the bench harness read it.
#[derive(Default)]
pub struct OpCounter {
    pub mult_cc: AtomicU64,
    pub mult_cp: AtomicU64,
    pub add_cc: AtomicU64,
    /// Table lookups (FHESGD baseline activations).
    pub tlu: AtomicU64,
    /// Bootstrapped TFHE gates (Glyph activations).
    pub act_gates: AtomicU64,
    /// Digit-extraction bootstraps (part of the BGV→TFHE switch).
    pub extract_pbs: AtomicU64,
    /// BGV→TFHE switches (per ciphertext).
    pub switch_b2t: AtomicU64,
    /// TFHE→BGV switches (per packed ciphertext).
    pub switch_t2b: AtomicU64,
    /// Noise refreshes (substituted bootstrapping, DESIGN.md §5).
    pub refresh: AtomicU64,
    /// BGV modulus switches.
    pub mod_switch: AtomicU64,
    /// BGV relinearizations (one per reference MultCC; one per *row* on the
    /// lazy-relin MAC engine — the saving `benches/bgv_mac.rs` reports).
    pub relin: AtomicU64,
    /// Lane extractions inside BGV→TFHE switches (SampleExtract + rescale +
    /// LWE key switch, one per requested coefficient position).
    pub extract_lanes: AtomicU64,
    /// Lanes packed inside TFHE→BGV switches (one per LWE entering a
    /// packing key switch).
    pub repack_lanes: AtomicU64,
}

/// A plain-value snapshot of [`OpCounter`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpSnapshot {
    pub mult_cc: u64,
    pub mult_cp: u64,
    pub add_cc: u64,
    pub tlu: u64,
    pub act_gates: u64,
    pub extract_pbs: u64,
    pub switch_b2t: u64,
    pub switch_t2b: u64,
    pub refresh: u64,
    pub mod_switch: u64,
    pub relin: u64,
    pub extract_lanes: u64,
    pub repack_lanes: u64,
}

impl OpCounter {
    pub fn snapshot(&self) -> OpSnapshot {
        OpSnapshot {
            mult_cc: self.mult_cc.load(Ordering::Relaxed),
            mult_cp: self.mult_cp.load(Ordering::Relaxed),
            add_cc: self.add_cc.load(Ordering::Relaxed),
            tlu: self.tlu.load(Ordering::Relaxed),
            act_gates: self.act_gates.load(Ordering::Relaxed),
            extract_pbs: self.extract_pbs.load(Ordering::Relaxed),
            switch_b2t: self.switch_b2t.load(Ordering::Relaxed),
            switch_t2b: self.switch_t2b.load(Ordering::Relaxed),
            refresh: self.refresh.load(Ordering::Relaxed),
            mod_switch: self.mod_switch.load(Ordering::Relaxed),
            relin: self.relin.load(Ordering::Relaxed),
            extract_lanes: self.extract_lanes.load(Ordering::Relaxed),
            repack_lanes: self.repack_lanes.load(Ordering::Relaxed),
        }
    }

    #[inline]
    pub fn bump(&self, field: &AtomicU64, by: u64) {
        field.fetch_add(by, Ordering::Relaxed);
    }

    /// Overwrite every counter with a snapshot's values. Used when resuming
    /// a checkpointed training run so the live counters continue exactly
    /// where the interrupted run left off.
    pub fn store(&self, s: &OpSnapshot) {
        self.mult_cc.store(s.mult_cc, Ordering::Relaxed);
        self.mult_cp.store(s.mult_cp, Ordering::Relaxed);
        self.add_cc.store(s.add_cc, Ordering::Relaxed);
        self.tlu.store(s.tlu, Ordering::Relaxed);
        self.act_gates.store(s.act_gates, Ordering::Relaxed);
        self.extract_pbs.store(s.extract_pbs, Ordering::Relaxed);
        self.switch_b2t.store(s.switch_b2t, Ordering::Relaxed);
        self.switch_t2b.store(s.switch_t2b, Ordering::Relaxed);
        self.refresh.store(s.refresh, Ordering::Relaxed);
        self.mod_switch.store(s.mod_switch, Ordering::Relaxed);
        self.relin.store(s.relin, Ordering::Relaxed);
        self.extract_lanes.store(s.extract_lanes, Ordering::Relaxed);
        self.repack_lanes.store(s.repack_lanes, Ordering::Relaxed);
    }
}

impl OpSnapshot {
    /// Difference since an earlier snapshot (per-layer accounting).
    pub fn since(&self, earlier: &OpSnapshot) -> OpSnapshot {
        OpSnapshot {
            mult_cc: self.mult_cc - earlier.mult_cc,
            mult_cp: self.mult_cp - earlier.mult_cp,
            add_cc: self.add_cc - earlier.add_cc,
            tlu: self.tlu - earlier.tlu,
            act_gates: self.act_gates - earlier.act_gates,
            extract_pbs: self.extract_pbs - earlier.extract_pbs,
            switch_b2t: self.switch_b2t - earlier.switch_b2t,
            switch_t2b: self.switch_t2b - earlier.switch_t2b,
            refresh: self.refresh - earlier.refresh,
            mod_switch: self.mod_switch - earlier.mod_switch,
            relin: self.relin - earlier.relin,
            extract_lanes: self.extract_lanes - earlier.extract_lanes,
            repack_lanes: self.repack_lanes - earlier.repack_lanes,
        }
    }

    /// Total homomorphic op count (the paper's HOP column).
    pub fn hop(&self) -> u64 {
        self.mult_cc + self.mult_cp + self.add_cc + self.tlu + self.act_gates
    }

    /// Every counter as a `(name, value)` pair, in declaration order. The
    /// single source of field names for metrics exposition, the wire codec,
    /// and diffing — new counters only need to be added here once.
    pub fn fields(&self) -> [(&'static str, u64); 13] {
        [
            ("mult_cc", self.mult_cc),
            ("mult_cp", self.mult_cp),
            ("add_cc", self.add_cc),
            ("tlu", self.tlu),
            ("act_gates", self.act_gates),
            ("extract_pbs", self.extract_pbs),
            ("switch_b2t", self.switch_b2t),
            ("switch_t2b", self.switch_t2b),
            ("refresh", self.refresh),
            ("mod_switch", self.mod_switch),
            ("relin", self.relin),
            ("extract_lanes", self.extract_lanes),
            ("repack_lanes", self.repack_lanes),
        ]
    }

    /// Rebuild a snapshot from `(name, value)` pairs ([`Self::fields`]'s
    /// inverse). Unknown names are rejected; missing names stay zero.
    pub fn from_fields<'a>(
        pairs: impl IntoIterator<Item = (&'a str, u64)>,
    ) -> Result<OpSnapshot, String> {
        let mut s = OpSnapshot::default();
        for (name, v) in pairs {
            match name {
                "mult_cc" => s.mult_cc = v,
                "mult_cp" => s.mult_cp = v,
                "add_cc" => s.add_cc = v,
                "tlu" => s.tlu = v,
                "act_gates" => s.act_gates = v,
                "extract_pbs" => s.extract_pbs = v,
                "switch_b2t" => s.switch_b2t = v,
                "switch_t2b" => s.switch_t2b = v,
                "refresh" => s.refresh = v,
                "mod_switch" => s.mod_switch = v,
                "relin" => s.relin = v,
                "extract_lanes" => s.extract_lanes = v,
                "repack_lanes" => s.repack_lanes = v,
                other => return Err(format!("unknown op counter {other:?}")),
            }
        }
        Ok(s)
    }

    /// Every counter scaled by `k` — a compiled plan's per-step totals times
    /// a step count is the *predicted* snapshot the serve layer prices
    /// against live counters.
    pub fn scale(&self, k: u64) -> OpSnapshot {
        OpSnapshot::from_fields(self.fields().iter().map(|&(n, v)| (n, v * k)))
            .expect("fields() names are always known")
    }

    /// Counter-wise sum — accumulates per-pass attribution shares into a
    /// job's running totals.
    pub fn plus(&self, other: &OpSnapshot) -> OpSnapshot {
        OpSnapshot::from_fields(
            self.fields().iter().zip(other.fields().iter()).map(|(&(n, a), &(_, b))| (n, a + b)),
        )
        .expect("fields() names are always known")
    }

    /// The slot range `[start, end)`'s *exact* proportional share of this
    /// snapshot, out of `total` slots: counter `v` contributes
    /// `⌊v·end/total⌋ − ⌊v·start/total⌋`. The telescoping floors guarantee
    /// that contiguous ranges covering `0..total` sum to `self` counter for
    /// counter — the property the serve layer's coalesced-batch op
    /// attribution needs (per-job shares of a shared `OpCounter` delta must
    /// reconstruct the delta exactly, or billing drifts).
    pub fn split_share(&self, start: u64, end: u64, total: u64) -> OpSnapshot {
        assert!(start <= end && end <= total && total > 0, "bad slot range {start}..{end}/{total}");
        let share = |v: u64| {
            ((v as u128 * end as u128) / total as u128 - (v as u128 * start as u128) / total as u128)
                as u64
        };
        OpSnapshot::from_fields(self.fields().iter().map(|&(n, v)| (n, share(v))))
            .expect("fields() names are always known")
    }

    /// Field-by-field comparison: every counter whose value differs, as
    /// `(name, self_value, other_value)`. Empty means identical.
    pub fn diff(&self, other: &OpSnapshot) -> Vec<(&'static str, u64, u64)> {
        self.diff_ignoring(other, &[])
    }

    /// [`Self::diff`] with some counters excluded — plan predictions carry
    /// no relin/mod-switch terms, so consistency checks ignore those.
    pub fn diff_ignoring(
        &self,
        other: &OpSnapshot,
        ignore: &[&str],
    ) -> Vec<(&'static str, u64, u64)> {
        self.fields()
            .iter()
            .zip(other.fields().iter())
            .filter(|((name, a), (_, b))| a != b && !ignore.contains(name))
            .map(|(&(name, a), &(_, b))| (name, a, b))
            .collect()
    }

    /// Render a [`Self::diff`] result for assertion messages:
    /// `name live=.. expected=..` lines.
    pub fn render_diff(diff: &[(&'static str, u64, u64)]) -> String {
        diff.iter()
            .map(|(name, a, b)| format!("{name}: live={a} expected={b}"))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

impl std::fmt::Display for OpSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "HOP={} MultCC={} MultCP={} AddCC={} TLU={} Act={} PBS={} B2T={} T2B={} refresh={} \
             relin={} extract={} repack={}",
            self.hop(),
            self.mult_cc,
            self.mult_cp,
            self.add_cc,
            self.tlu,
            self.act_gates,
            self.extract_pbs,
            self.switch_b2t,
            self.switch_t2b,
            self.refresh,
            self.relin,
            self.extract_lanes,
            self.repack_lanes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_and_since() {
        let c = OpCounter::default();
        c.bump(&c.mult_cc, 5);
        c.bump(&c.add_cc, 3);
        let s1 = c.snapshot();
        assert_eq!(s1.mult_cc, 5);
        assert_eq!(s1.hop(), 8);
        c.bump(&c.mult_cc, 2);
        let s2 = c.snapshot();
        let d = s2.since(&s1);
        assert_eq!(d.mult_cc, 2);
        assert_eq!(d.add_cc, 0);
    }

    #[test]
    fn fields_roundtrip_and_diff() {
        let c = OpCounter::default();
        c.bump(&c.mult_cc, 7);
        c.bump(&c.relin, 2);
        let s = c.snapshot();
        let back = OpSnapshot::from_fields(s.fields()).unwrap();
        assert_eq!(s, back);
        assert!(OpSnapshot::from_fields([("bogus", 1)]).is_err());

        let mut other = s;
        other.relin = 0;
        other.add_cc = 9;
        let d = s.diff(&other);
        assert_eq!(d, vec![("add_cc", 0, 9), ("relin", 2, 0)]);
        assert!(s.diff_ignoring(&other, &["relin", "add_cc"]).is_empty());
        let msg = OpSnapshot::render_diff(&d);
        assert!(msg.contains("add_cc: live=0 expected=9"), "{msg}");

        assert_eq!(s.scale(3).mult_cc, 21);
        assert_eq!(s.scale(0), OpSnapshot::default());
    }

    #[test]
    fn split_share_is_exact_and_telescoping() {
        let s = OpSnapshot { mult_cc: 7, add_cc: 1, act_gates: 1000, relin: 3, ..Default::default() };
        // three uneven contiguous ranges must reconstruct the snapshot exactly
        let parts =
            [s.split_share(0, 3, 8), s.split_share(3, 4, 8), s.split_share(4, 8, 8)];
        let mut sum = OpSnapshot::default();
        for p in &parts {
            sum = OpSnapshot::from_fields(
                sum.fields().iter().zip(p.fields().iter()).map(|(&(n, a), &(_, b))| (n, a + b)),
            )
            .unwrap();
        }
        assert_eq!(sum, s, "shares must telescope back to the whole");
        // a full-range share is the identity; an empty range is zero
        assert_eq!(s.split_share(0, 8, 8), s);
        assert_eq!(s.split_share(5, 5, 8), OpSnapshot::default());
        // indivisible counts round per-range but never drop or double-count
        let odd = OpSnapshot { mult_cc: 5, ..Default::default() };
        let a = odd.split_share(0, 1, 2);
        let b = odd.split_share(1, 2, 2);
        assert_eq!(a.mult_cc + b.mult_cc, 5);
    }

    #[test]
    fn store_overwrites_counters() {
        let c = OpCounter::default();
        c.bump(&c.mult_cc, 5);
        let mut s = c.snapshot();
        s.tlu = 11;
        s.mult_cc = 1;
        c.store(&s);
        assert_eq!(c.snapshot(), s);
    }
}
