//! Homomorphic-operation accounting — the HOP / MultCC / MultCP / AddCC /
//! TLU / Act / Switch columns of the paper's Tables 2–4 and 6–8.

use std::sync::atomic::{AtomicU64, Ordering};

/// Thread-safe operation counters. One per engine; layers and training
/// loops record into it, the cost model and the bench harness read it.
#[derive(Default)]
pub struct OpCounter {
    pub mult_cc: AtomicU64,
    pub mult_cp: AtomicU64,
    pub add_cc: AtomicU64,
    /// Table lookups (FHESGD baseline activations).
    pub tlu: AtomicU64,
    /// Bootstrapped TFHE gates (Glyph activations).
    pub act_gates: AtomicU64,
    /// Digit-extraction bootstraps (part of the BGV→TFHE switch).
    pub extract_pbs: AtomicU64,
    /// BGV→TFHE switches (per ciphertext).
    pub switch_b2t: AtomicU64,
    /// TFHE→BGV switches (per packed ciphertext).
    pub switch_t2b: AtomicU64,
    /// Noise refreshes (substituted bootstrapping, DESIGN.md §5).
    pub refresh: AtomicU64,
    /// BGV modulus switches.
    pub mod_switch: AtomicU64,
    /// BGV relinearizations (one per reference MultCC; one per *row* on the
    /// lazy-relin MAC engine — the saving `benches/bgv_mac.rs` reports).
    pub relin: AtomicU64,
    /// Lane extractions inside BGV→TFHE switches (SampleExtract + rescale +
    /// LWE key switch, one per requested coefficient position).
    pub extract_lanes: AtomicU64,
    /// Lanes packed inside TFHE→BGV switches (one per LWE entering a
    /// packing key switch).
    pub repack_lanes: AtomicU64,
}

/// A plain-value snapshot of [`OpCounter`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpSnapshot {
    pub mult_cc: u64,
    pub mult_cp: u64,
    pub add_cc: u64,
    pub tlu: u64,
    pub act_gates: u64,
    pub extract_pbs: u64,
    pub switch_b2t: u64,
    pub switch_t2b: u64,
    pub refresh: u64,
    pub mod_switch: u64,
    pub relin: u64,
    pub extract_lanes: u64,
    pub repack_lanes: u64,
}

impl OpCounter {
    pub fn snapshot(&self) -> OpSnapshot {
        OpSnapshot {
            mult_cc: self.mult_cc.load(Ordering::Relaxed),
            mult_cp: self.mult_cp.load(Ordering::Relaxed),
            add_cc: self.add_cc.load(Ordering::Relaxed),
            tlu: self.tlu.load(Ordering::Relaxed),
            act_gates: self.act_gates.load(Ordering::Relaxed),
            extract_pbs: self.extract_pbs.load(Ordering::Relaxed),
            switch_b2t: self.switch_b2t.load(Ordering::Relaxed),
            switch_t2b: self.switch_t2b.load(Ordering::Relaxed),
            refresh: self.refresh.load(Ordering::Relaxed),
            mod_switch: self.mod_switch.load(Ordering::Relaxed),
            relin: self.relin.load(Ordering::Relaxed),
            extract_lanes: self.extract_lanes.load(Ordering::Relaxed),
            repack_lanes: self.repack_lanes.load(Ordering::Relaxed),
        }
    }

    #[inline]
    pub fn bump(&self, field: &AtomicU64, by: u64) {
        field.fetch_add(by, Ordering::Relaxed);
    }
}

impl OpSnapshot {
    /// Difference since an earlier snapshot (per-layer accounting).
    pub fn since(&self, earlier: &OpSnapshot) -> OpSnapshot {
        OpSnapshot {
            mult_cc: self.mult_cc - earlier.mult_cc,
            mult_cp: self.mult_cp - earlier.mult_cp,
            add_cc: self.add_cc - earlier.add_cc,
            tlu: self.tlu - earlier.tlu,
            act_gates: self.act_gates - earlier.act_gates,
            extract_pbs: self.extract_pbs - earlier.extract_pbs,
            switch_b2t: self.switch_b2t - earlier.switch_b2t,
            switch_t2b: self.switch_t2b - earlier.switch_t2b,
            refresh: self.refresh - earlier.refresh,
            mod_switch: self.mod_switch - earlier.mod_switch,
            relin: self.relin - earlier.relin,
            extract_lanes: self.extract_lanes - earlier.extract_lanes,
            repack_lanes: self.repack_lanes - earlier.repack_lanes,
        }
    }

    /// Total homomorphic op count (the paper's HOP column).
    pub fn hop(&self) -> u64 {
        self.mult_cc + self.mult_cp + self.add_cc + self.tlu + self.act_gates
    }
}

impl std::fmt::Display for OpSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "HOP={} MultCC={} MultCP={} AddCC={} TLU={} Act={} PBS={} B2T={} T2B={} refresh={} \
             relin={} extract={} repack={}",
            self.hop(),
            self.mult_cc,
            self.mult_cp,
            self.add_cc,
            self.tlu,
            self.act_gates,
            self.extract_pbs,
            self.switch_b2t,
            self.switch_t2b,
            self.refresh,
            self.relin,
            self.extract_lanes,
            self.repack_lanes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_and_since() {
        let c = OpCounter::default();
        c.bump(&c.mult_cc, 5);
        c.bump(&c.add_cc, 3);
        let s1 = c.snapshot();
        assert_eq!(s1.mult_cc, 5);
        assert_eq!(s1.hop(), 8);
        c.bump(&c.mult_cc, 2);
        let s2 = c.snapshot();
        let d = s2.since(&s1);
        assert_eq!(d.mult_cc, 2);
        assert_eq!(d.add_cc, 0);
    }
}
