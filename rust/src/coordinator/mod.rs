//! L3 coordination: cryptosystem scheduling, parallel execution, HOP
//! metrics and the calibrated cost model that regenerates the paper's
//! tables.

pub mod cost;
pub mod executor;
pub mod metrics;
pub mod scheduler;

pub use cost::{
    cnn_paper_plan, cnn_table, mlp_paper_plan, mlp_table, price_plan, price_step, to_markdown,
    total_row, CnnShape, OpLatencies, Scheme, TableRow,
};
pub use executor::{max_threads, parallel_map, GlyphPool, WorkerScratch};
pub use metrics::{OpCounter, OpSnapshot};
pub use scheduler::{LayerKind, Plan, PlanLayer, PlanStep, StepOps, StepPhase, System};
