//! L3 coordination: cryptosystem scheduling, parallel execution, HOP
//! metrics and the calibrated cost model that regenerates the paper's
//! tables.

pub mod cost;
pub mod executor;
pub mod metrics;
pub mod scheduler;

pub use cost::{mlp_table, cnn_table, to_markdown, total_row, CnnShape, OpLatencies, Scheme, TableRow};
pub use executor::{max_threads, parallel_map, GlyphPool};
pub use metrics::{OpCounter, OpSnapshot};
pub use scheduler::{LayerKind, Plan, PlanStep, System};
