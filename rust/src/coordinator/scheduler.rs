//! Cryptosystem scheduling: assigns each network operation to BGV or TFHE
//! and inserts the switches (the "Switch" column of Tables 3/4/7/8).
//!
//! The policy is the paper's: vectorial arithmetic (FC/conv/pool/BN/loss)
//! on BGV, nonlinear activations on TFHE, switch at every boundary, and
//! keep the quadratic loss on BGV because a switch would cost more than it
//! saves (§4.1).

/// A network layer, as the scheduler sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Fc { trainable: bool },
    Conv { trainable: bool },
    BatchNorm,
    AvgPool,
    Relu,
    Softmax,
    QuadraticLoss,
}

/// Which cryptosystem executes a step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum System {
    Bgv,
    Tfhe,
}

/// One scheduled step.
#[derive(Clone, Debug)]
pub struct PlanStep {
    pub name: String,
    pub system: System,
    /// Switch annotation entering this step ("BGV-TFHE", "TFHE-BGV" or "-").
    pub switch: &'static str,
}

/// A full schedule.
pub struct Plan {
    pub steps: Vec<PlanStep>,
}

impl Plan {
    /// Build the forward+backward schedule for a layer stack.
    pub fn build(layers: &[(String, LayerKind)]) -> Plan {
        let system_of = |k: LayerKind| match k {
            LayerKind::Relu | LayerKind::Softmax => System::Tfhe,
            _ => System::Bgv,
        };
        let mut steps = Vec::new();
        let mut cur = System::Bgv;
        let mut push = |name: String, sys: System, cur: &mut System| {
            let switch = match (*cur, sys) {
                (System::Bgv, System::Tfhe) => "BGV-TFHE",
                (System::Tfhe, System::Bgv) => "TFHE-BGV",
                _ => "-",
            };
            steps.push(PlanStep { name, system: sys, switch });
            *cur = sys;
        };
        // forward
        for (name, kind) in layers {
            push(format!("{name}-forward"), system_of(*kind), &mut cur);
        }
        // backward (reverse order; trainable layers also emit a gradient step)
        for (name, kind) in layers.iter().rev() {
            match kind {
                LayerKind::QuadraticLoss => push(format!("{name}-error"), System::Bgv, &mut cur),
                LayerKind::Relu | LayerKind::Softmax => {
                    push(format!("{name}-error"), System::Tfhe, &mut cur)
                }
                LayerKind::Fc { trainable } | LayerKind::Conv { trainable } => {
                    push(format!("{name}-error"), System::Bgv, &mut cur);
                    if *trainable {
                        push(format!("{name}-gradient"), System::Bgv, &mut cur);
                    }
                }
                _ => {} // pool/BN backward folded into neighbours under TL
            }
        }
        Plan { steps }
    }

    /// Number of switches in the plan.
    pub fn switch_count(&self) -> usize {
        self.steps.iter().filter(|s| s.switch != "-").count()
    }

    /// Invariant: switches alternate correctly (every BGV→TFHE is eventually
    /// followed by TFHE→BGV, never two of the same direction in a row).
    pub fn validate(&self) -> bool {
        let mut cur = System::Bgv;
        for s in &self.steps {
            match s.switch {
                "BGV-TFHE" => {
                    if cur != System::Bgv {
                        return false;
                    }
                    cur = System::Tfhe;
                }
                "TFHE-BGV" => {
                    if cur != System::Tfhe {
                        return false;
                    }
                    cur = System::Bgv;
                }
                _ => {}
            }
        }
        true
    }
}

/// The paper's 3-layer MLP schedule.
pub fn mlp_plan() -> Plan {
    Plan::build(&[
        ("FC1".into(), LayerKind::Fc { trainable: true }),
        ("Act1".into(), LayerKind::Relu),
        ("FC2".into(), LayerKind::Fc { trainable: true }),
        ("Act2".into(), LayerKind::Relu),
        ("FC3".into(), LayerKind::Fc { trainable: true }),
        ("Act3".into(), LayerKind::Softmax),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_plan_alternates_switches() {
        let plan = mlp_plan();
        assert!(plan.validate());
        // forward: 3 FC→Act boundaries ×2 directions = 6 switches, plus the
        // backward activations' boundaries.
        assert!(plan.switch_count() >= 6);
        // activations run on TFHE, FCs on BGV
        for s in &plan.steps {
            if s.name.starts_with("Act") && !s.name.contains("error") {
                assert_eq!(s.system, System::Tfhe, "{}", s.name);
            }
            if s.name.starts_with("FC") {
                assert_eq!(s.system, System::Bgv, "{}", s.name);
            }
        }
    }

    #[test]
    fn transfer_cnn_plan_has_no_conv_gradients() {
        let plan = Plan::build(&[
            ("Conv1".into(), LayerKind::Conv { trainable: false }),
            ("BN1".into(), LayerKind::BatchNorm),
            ("Act1".into(), LayerKind::Relu),
            ("Pool1".into(), LayerKind::AvgPool),
            ("FC1".into(), LayerKind::Fc { trainable: true }),
            ("Act3".into(), LayerKind::Softmax),
        ]);
        assert!(plan.validate());
        assert!(!plan.steps.iter().any(|s| s.name == "Conv1-gradient"));
        assert!(plan.steps.iter().any(|s| s.name == "FC1-gradient"));
    }
}
