//! Cryptosystem scheduling: the *executable* `Plan` that assigns every
//! network step to BGV or TFHE and inserts the switches (the "Switch"
//! column of Tables 3/4/7/8).
//!
//! A [`Plan`] is no longer a print-only artifact. It is compiled from a
//! `nn::network::Network` (each unit contributes a [`PlanLayer`] through the
//! `Layer::plan_entry` trait method) and is the single source of truth for
//!
//! * **execution** — `Network::forward`/`train_step` walk the plan's steps
//!   in order; activation steps are exactly where `switch_to_bits` /
//!   `switch_to_bgv` run, and gradient steps exist only where the plan says
//!   a layer trains;
//! * **the cost model** — `coordinator::cost::price_plan` turns a plan's
//!   per-step [`StepOps`] into the paper's latency tables;
//! * **the CLI** — `glyph plan [--cnn] [--dims ...]` prints the compiled
//!   schedule.
//!
//! The policy is the paper's (§4.1): vectorial arithmetic (FC/conv/pool/
//! BN/loss) on BGV, nonlinear activations on TFHE, switch at every
//! boundary, and keep the quadratic-loss derivative on BGV because a switch
//! would cost more than it saves. The backward walk is truncated below the
//! lowest trainable layer (transfer learning freezes the feature extractor,
//! so no error ever needs to reach it), and within a trainable layer the
//! canonical order is error-then-gradient, matching Tables 3/4.

/// A network layer, as the scheduler sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Fc { trainable: bool },
    Conv { trainable: bool },
    BatchNorm,
    AvgPool,
    /// Shape-only CHW→vector adapter (zero homomorphic ops).
    Flatten,
    Relu,
    Softmax,
    /// FHESGD-baseline sigmoid via the bit-sliced BGV table lookup.
    SigmoidTlu,
    QuadraticLoss,
}

/// Which cryptosystem executes a step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum System {
    Bgv,
    Tfhe,
}

/// Which phase of the mini-batch step a plan entry belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPhase {
    Forward,
    /// Error propagation (the paper's `*-error` rows).
    Error,
    /// Weight gradient + SGD update (the paper's `*-gradient` rows).
    Gradient,
}

/// Exact homomorphic-op counts predicted for one plan step of one
/// mini-batch iteration. Field meanings mirror `coordinator::metrics::
/// OpCounter`, so a compiled plan's [`Plan::totals`] can be compared 1:1
/// against a live counter snapshot (the plan/execution consistency test).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StepOps {
    pub mult_cc: u64,
    pub mult_cp: u64,
    pub add_cc: u64,
    /// Bit-sliced BGV table lookups (FHESGD activations).
    pub tlu: u64,
    /// Values through the TFHE ReLU/iReLU (per-neuron, batch amortized).
    pub relu_values: u64,
    /// Values through the Figure-4 softmax unit (per-neuron).
    pub softmax_values: u64,
    /// Bootstrapped TFHE gates.
    pub act_gates: u64,
    /// Digit-extraction bootstraps inside BGV→TFHE switches.
    pub extract_pbs: u64,
    /// BGV→TFHE switches (per ciphertext).
    pub switch_b2t: u64,
    /// TFHE→BGV switches (per packed ciphertext).
    pub switch_t2b: u64,
    /// Noise refreshes (each T2B packs into a fresh ciphertext; each TLU
    /// performs two domain conversions).
    pub refresh: u64,
    /// Lane extractions inside B2T switches (one per coefficient position).
    pub extract_lanes: u64,
    /// Lanes packed inside T2B switches (one per LWE entering the packing
    /// key switch).
    pub repack_lanes: u64,
}

impl StepOps {
    /// Values through any TFHE activation (the paper's "Act" column).
    pub fn act_values(&self) -> u64 {
        self.relu_values + self.softmax_values
    }

    /// Predicted counts as a live-counter-shaped snapshot. `relin` and
    /// `mod_switch` have no plan-level prediction (they depend on the MAC
    /// engine's laziness) and stay zero — compare with
    /// `OpSnapshot::diff_ignoring(.., &["relin", "mod_switch"])`.
    pub fn to_snapshot(&self) -> crate::coordinator::metrics::OpSnapshot {
        crate::coordinator::metrics::OpSnapshot {
            mult_cc: self.mult_cc,
            mult_cp: self.mult_cp,
            add_cc: self.add_cc,
            tlu: self.tlu,
            act_gates: self.act_gates,
            extract_pbs: self.extract_pbs,
            switch_b2t: self.switch_b2t,
            switch_t2b: self.switch_t2b,
            refresh: self.refresh,
            mod_switch: 0,
            relin: 0,
            extract_lanes: self.extract_lanes,
            repack_lanes: self.repack_lanes,
        }
    }

    /// Element-wise accumulate (used by [`Plan::totals`]).
    pub fn accumulate(&mut self, o: &StepOps) {
        self.mult_cc += o.mult_cc;
        self.mult_cp += o.mult_cp;
        self.add_cc += o.add_cc;
        self.tlu += o.tlu;
        self.relu_values += o.relu_values;
        self.softmax_values += o.softmax_values;
        self.act_gates += o.act_gates;
        self.extract_pbs += o.extract_pbs;
        self.switch_b2t += o.switch_b2t;
        self.switch_t2b += o.switch_t2b;
        self.refresh += o.refresh;
        self.extract_lanes += o.extract_lanes;
        self.repack_lanes += o.repack_lanes;
    }
}

/// One scheduled step.
#[derive(Clone, Debug)]
pub struct PlanStep {
    pub name: String,
    /// Index of the `Network` unit that executes this step (`None` for
    /// paper-calibrated table plans that are not backed by a live network).
    pub unit: Option<usize>,
    pub phase: StepPhase,
    pub system: System,
    /// Switch annotation ("BGV-TFHE", "TFHE-BGV" or "-"). Compiled plans
    /// annotate the boundary *entering* the step; paper table plans carry
    /// the paper's own column convention.
    pub switch: &'static str,
    /// Predicted op counts for this step.
    pub ops: StepOps,
    /// Paper cost-model quirk: the Δ/extract half of a switch rides on the
    /// producing FC row as a +0.96% latency overhead (§4.2).
    pub fc_switch_overhead: bool,
}

/// Scheduler-facing description of one network unit: what `Layer::
/// plan_entry` returns, and what [`Plan::from_layers`] consumes.
#[derive(Clone, Debug)]
pub struct PlanLayer {
    pub name: String,
    pub kind: LayerKind,
    /// Index of the backing `Network` unit, if any.
    pub unit: Option<usize>,
    /// Forward-step op counts.
    pub forward: StepOps,
    /// Error-step op counts; `None` when the unit cannot (or need not)
    /// propagate an error (frozen conv/BN/pool fold into neighbours).
    pub error: Option<StepOps>,
    /// Gradient-step op counts; `None` for frozen units.
    pub gradient: Option<StepOps>,
}

/// A full schedule.
pub struct Plan {
    pub steps: Vec<PlanStep>,
}

fn forward_system(kind: LayerKind) -> System {
    match kind {
        LayerKind::Relu | LayerKind::Softmax => System::Tfhe,
        _ => System::Bgv,
    }
}

fn error_system(kind: LayerKind) -> System {
    match kind {
        // iReLU runs Algorithm-2 gates on TFHE; the softmax *error* is the
        // quadratic-loss derivative, one SubCC on BGV (Eq. 6).
        LayerKind::Relu => System::Tfhe,
        _ => System::Bgv,
    }
}

impl Plan {
    /// Build the forward+backward schedule from per-unit plan entries.
    ///
    /// Policy (matches `Network::train_step` exactly):
    /// * forward steps in layer order;
    /// * backward in reverse order, truncated below the lowest trainable
    ///   layer: a unit emits its error step only if some trainable layer
    ///   sits strictly below it;
    /// * within a layer, error before gradient (the Tables-3/4 row order).
    pub fn from_layers(layers: &[PlanLayer]) -> Plan {
        let mut steps = Vec::new();
        let mut cur = System::Bgv;
        let mut push = |name: String,
                        unit: Option<usize>,
                        phase: StepPhase,
                        sys: System,
                        ops: StepOps,
                        cur: &mut System| {
            let switch = match (*cur, sys) {
                (System::Bgv, System::Tfhe) => "BGV-TFHE",
                (System::Tfhe, System::Bgv) => "TFHE-BGV",
                _ => "-",
            };
            steps.push(PlanStep {
                name,
                unit,
                phase,
                system: sys,
                switch,
                ops,
                fc_switch_overhead: false,
            });
            *cur = sys;
        };

        for l in layers {
            push(
                format!("{}-forward", l.name),
                l.unit,
                StepPhase::Forward,
                forward_system(l.kind),
                l.forward,
                &mut cur,
            );
        }
        for (idx, l) in layers.iter().enumerate().rev() {
            let trainable_below = layers[..idx].iter().any(|b| b.gradient.is_some());
            if trainable_below {
                if let Some(ops) = l.error {
                    push(
                        format!("{}-error", l.name),
                        l.unit,
                        StepPhase::Error,
                        error_system(l.kind),
                        ops,
                        &mut cur,
                    );
                }
            }
            if let Some(ops) = l.gradient {
                push(
                    format!("{}-gradient", l.name),
                    l.unit,
                    StepPhase::Gradient,
                    System::Bgv,
                    ops,
                    &mut cur,
                );
            }
        }
        Plan { steps }
    }

    /// Compatibility constructor: schedule a bare layer stack (no op
    /// counts). Error/gradient presence is derived from the kind alone.
    pub fn build(layers: &[(String, LayerKind)]) -> Plan {
        let entries: Vec<PlanLayer> = layers
            .iter()
            .map(|(name, kind)| {
                let error = match kind {
                    LayerKind::BatchNorm | LayerKind::AvgPool | LayerKind::Flatten => None,
                    _ => Some(StepOps::default()),
                };
                let gradient = match kind {
                    LayerKind::Fc { trainable: true } | LayerKind::Conv { trainable: true } => {
                        Some(StepOps::default())
                    }
                    _ => None,
                };
                PlanLayer {
                    name: name.clone(),
                    kind: *kind,
                    unit: None,
                    forward: StepOps::default(),
                    error,
                    gradient,
                }
            })
            .collect();
        Plan::from_layers(&entries)
    }

    /// The forward-only (inference) schedule: the plan with every
    /// error/gradient step dropped.
    ///
    /// [`Plan::from_layers`] emits all forward steps first, in layer order,
    /// so on a compiled plan this is exactly the forward *prefix* — the
    /// switch annotations (computed sequentially while building) stay
    /// valid, [`Plan::validate`] still holds, and [`Plan::totals`] prices
    /// one batched forward pass exactly. `Network::forward` walks only
    /// `StepPhase::Forward` steps, so live op counters across one forward
    /// pass equal this plan's totals (up to the unpredicted relin/
    /// mod-switch counters), which is the inference-workload half of the
    /// plan/execution consistency contract.
    pub fn forward_only(&self) -> Plan {
        Plan {
            steps: self
                .steps
                .iter()
                .filter(|s| s.phase == StepPhase::Forward)
                .cloned()
                .collect(),
        }
    }

    /// Number of switches in the plan.
    pub fn switch_count(&self) -> usize {
        self.steps.iter().filter(|s| s.switch != "-").count()
    }

    /// Sum of the per-step predicted op counts — directly comparable to an
    /// `OpCounter` snapshot taken across one live `train_step`.
    pub fn totals(&self) -> StepOps {
        let mut t = StepOps::default();
        for s in &self.steps {
            t.accumulate(&s.ops);
        }
        t
    }

    /// Invariant: switches alternate correctly (every BGV→TFHE is eventually
    /// followed by TFHE→BGV, never two of the same direction in a row).
    pub fn validate(&self) -> bool {
        let mut cur = System::Bgv;
        for s in &self.steps {
            match s.switch {
                "BGV-TFHE" => {
                    if cur != System::Bgv {
                        return false;
                    }
                    cur = System::Tfhe;
                }
                "TFHE-BGV" => {
                    if cur != System::Tfhe {
                        return false;
                    }
                    cur = System::Bgv;
                }
                _ => {}
            }
        }
        true
    }
}

/// The paper's 3-layer MLP schedule (shape only; for the op-counted,
/// executable plan compile a `Network` or use `NetworkBuilder::compile`).
pub fn mlp_plan() -> Plan {
    Plan::build(&[
        ("FC1".into(), LayerKind::Fc { trainable: true }),
        ("Act1".into(), LayerKind::Relu),
        ("FC2".into(), LayerKind::Fc { trainable: true }),
        ("Act2".into(), LayerKind::Relu),
        ("FC3".into(), LayerKind::Fc { trainable: true }),
        ("Act3".into(), LayerKind::Softmax),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_plan_alternates_switches() {
        let plan = mlp_plan();
        assert!(plan.validate());
        // forward: 3 FC→Act boundaries ×2 directions = 6 switches, plus the
        // backward activations' boundaries.
        assert!(plan.switch_count() >= 6);
        // activations run on TFHE, FCs on BGV
        for s in &plan.steps {
            if s.name.starts_with("Act") && !s.name.contains("error") {
                assert_eq!(s.system, System::Tfhe, "{}", s.name);
            }
            if s.name.starts_with("FC") {
                assert_eq!(s.system, System::Bgv, "{}", s.name);
            }
        }
    }

    #[test]
    fn mlp_plan_orders_error_before_gradient() {
        let plan = mlp_plan();
        let pos = |n: &str| plan.steps.iter().position(|s| s.name == n).unwrap();
        assert!(pos("FC3-error") < pos("FC3-gradient"));
        assert!(pos("FC3-gradient") < pos("Act2-error"));
        // the lowest trainable layer has no error step (nothing below it
        // needs the signal)
        assert!(!plan.steps.iter().any(|s| s.name == "FC1-error"));
        assert!(plan.steps.iter().any(|s| s.name == "FC1-gradient"));
    }

    #[test]
    fn transfer_cnn_plan_has_no_conv_gradients() {
        let plan = Plan::build(&[
            ("Conv1".into(), LayerKind::Conv { trainable: false }),
            ("BN1".into(), LayerKind::BatchNorm),
            ("Act1".into(), LayerKind::Relu),
            ("Pool1".into(), LayerKind::AvgPool),
            ("FC1".into(), LayerKind::Fc { trainable: true }),
            ("Act3".into(), LayerKind::Softmax),
        ]);
        assert!(plan.validate());
        assert!(!plan.steps.iter().any(|s| s.name == "Conv1-gradient"));
        assert!(plan.steps.iter().any(|s| s.name == "FC1-gradient"));
        // backward truncates below the trainable head: the frozen ReLU never
        // propagates an error.
        assert!(!plan.steps.iter().any(|s| s.name == "Act1-error"));
    }

    #[test]
    fn forward_only_drops_every_backward_step() {
        let plan = mlp_plan();
        let fwd = plan.forward_only();
        assert!(fwd.validate());
        assert!(fwd.steps.iter().all(|s| s.phase == StepPhase::Forward));
        assert!(!fwd.steps.iter().any(|s| s.name.ends_with("-error")));
        assert!(!fwd.steps.iter().any(|s| s.name.ends_with("-gradient")));
        // the forward steps are the plan's prefix, switch annotations intact
        let n = fwd.steps.len();
        assert_eq!(n, 6);
        for (a, b) in plan.steps.iter().zip(&fwd.steps) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.switch, b.switch);
            assert_eq!(a.system, b.system);
        }
    }

    #[test]
    fn forward_only_totals_are_the_forward_op_counts() {
        let fc = StepOps { mult_cc: 12, add_cc: 8, ..Default::default() };
        let act = StepOps { switch_b2t: 4, switch_t2b: 4, act_gates: 56, refresh: 4, ..Default::default() };
        let plan = Plan::from_layers(&[
            PlanLayer {
                name: "FC1".into(),
                kind: LayerKind::Fc { trainable: true },
                unit: Some(0),
                forward: fc,
                error: Some(fc),
                gradient: Some(fc),
            },
            PlanLayer {
                name: "Act1".into(),
                kind: LayerKind::Relu,
                unit: Some(1),
                forward: act,
                error: Some(act),
                gradient: None,
            },
        ]);
        let fwd = plan.forward_only();
        let t = fwd.totals();
        // exactly one FC forward + one Act forward — no backward counts
        assert_eq!(t.mult_cc, 12);
        assert_eq!(t.add_cc, 8);
        assert_eq!(t.act_gates, 56);
        assert_eq!(t.switch_b2t, 4);
        assert_eq!(t.switch_t2b, 4);
    }

    #[test]
    fn totals_accumulate_step_ops() {
        let fc = StepOps { mult_cc: 12, add_cc: 8, ..Default::default() };
        let act = StepOps { switch_b2t: 4, switch_t2b: 4, act_gates: 56, refresh: 4, ..Default::default() };
        let plan = Plan::from_layers(&[
            PlanLayer {
                name: "FC1".into(),
                kind: LayerKind::Fc { trainable: true },
                unit: Some(0),
                forward: fc,
                error: Some(fc),
                gradient: Some(fc),
            },
            PlanLayer {
                name: "Act1".into(),
                kind: LayerKind::Relu,
                unit: Some(1),
                forward: act,
                error: Some(act),
                gradient: None,
            },
        ]);
        // backward truncation: Act1 error needs FC1 below (trainable ✓);
        // FC1 has no trainable below, so no FC1-error.
        let names: Vec<&str> = plan.steps.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["FC1-forward", "Act1-forward", "Act1-error", "FC1-gradient"]);
        let t = plan.totals();
        assert_eq!(t.mult_cc, 24);
        assert_eq!(t.act_gates, 112);
        assert_eq!(t.switch_b2t, 8);
    }
}
