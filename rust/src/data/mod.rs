//! Datasets: an MNIST IDX loader (used when `data/` holds the real files)
//! with deterministic synthetic fallbacks matching the paper's shapes
//! (28×28 MNIST, 28×28×3 Skin-Cancer-MNIST, plus SVHN/CIFAR-like *source*
//! distributions for transfer-learning pre-training). No network access is
//! available in this environment, so the synthetic generators are the
//! documented substitution (DESIGN.md §5): class-conditional templates +
//! deformations, with a shared low-level structure between source and
//! target pairs so that transfer learning has real signal to reuse.

use crate::math::rng::GlyphRng;
use std::fmt;
use std::io::Read;
use std::path::Path;

/// Dataset access failure: descriptive instead of an index panic deep in
/// the loader (the `SwitchError`/`EncodingError` convention).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DataError {
    /// A sample index past the dataset's length.
    SampleOutOfRange { index: usize, len: usize },
    /// An operation that needs at least one sample ran on an empty dataset.
    EmptyDataset { name: String },
    /// A requested minibatch runs past the end of the dataset.
    BatchOutOfRange { start: usize, batch: usize, len: usize },
}

impl fmt::Display for DataError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DataError::SampleOutOfRange { index, len } => {
                write!(f, "sample index {index} out of range for a dataset of {len} images")
            }
            DataError::EmptyDataset { name } => {
                write!(f, "dataset {name:?} is empty — nothing to sample")
            }
            DataError::BatchOutOfRange { start, batch, len } => write!(
                f,
                "minibatch [{start}, {}) runs past the dataset's {len} images",
                start + batch
            ),
        }
    }
}

impl std::error::Error for DataError {}

/// A dataset of images (f32 in [0,1]) with labels.
pub struct Dataset {
    /// (C, H, W)
    pub shape: (usize, usize, usize),
    pub images: Vec<Vec<f32>>,
    pub labels: Vec<usize>,
    pub classes: usize,
    pub name: String,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Quantize image `i` to signed 8-bit (pixel·127), validating the index
    /// (and distinguishing the empty-dataset case) instead of panicking.
    pub fn try_image_i8(&self, i: usize) -> Result<Vec<i64>, DataError> {
        if self.images.is_empty() {
            return Err(DataError::EmptyDataset { name: self.name.clone() });
        }
        let img = self
            .images
            .get(i)
            .ok_or(DataError::SampleOutOfRange { index: i, len: self.images.len() })?;
        Ok(img.iter().map(|&p| (p * 127.0).round() as i64).collect())
    }

    /// [`Self::try_image_i8`], panicking with the descriptive error.
    pub fn image_i8(&self, i: usize) -> Vec<i64> {
        self.try_image_i8(i).unwrap_or_else(|e| panic!("image_i8: {e}"))
    }

    /// The pixel count of one image (C·H·W).
    pub fn pixels(&self) -> usize {
        let (c, h, w) = self.shape;
        c * h * w
    }

    /// Quantized feature columns of one minibatch: `cols[f][b]` = feature
    /// `f` of sample `start+b`, with `features` pixels sampled evenly
    /// across the image when narrower than the full image (the CLI's
    /// subsampling convention). Also returns the batch's labels.
    pub fn minibatch(
        &self,
        start: usize,
        batch: usize,
        features: usize,
    ) -> Result<(Vec<Vec<i64>>, Vec<usize>), DataError> {
        if self.images.is_empty() {
            return Err(DataError::EmptyDataset { name: self.name.clone() });
        }
        if start + batch > self.len() {
            return Err(DataError::BatchOutOfRange { start, batch, len: self.len() });
        }
        let px = self.pixels();
        let imgs: Vec<Vec<i64>> =
            (0..batch).map(|b| self.try_image_i8(start + b)).collect::<Result<_, _>>()?;
        let cols = (0..features)
            .map(|f| {
                let p = if features > 1 { f * (px - 1) / (features - 1) } else { 0 };
                (0..batch).map(|b| imgs[b][p]).collect()
            })
            .collect();
        let labels = self.labels[start..start + batch].to_vec();
        Ok((cols, labels))
    }

    /// [`Self::minibatch`] that tolerates a ragged final batch: slots past
    /// the dataset's end are zero-filled and reported vacant in the
    /// returned occupancy mask (`occupied[b]` ⇔ slot `b` carries a real
    /// sample). Labels cover only the occupied slots. Errors if the window
    /// holds no real sample at all — padding an entirely-vacant batch is a
    /// caller bug, not a dataset condition.
    pub fn minibatch_padded(
        &self,
        start: usize,
        batch: usize,
        features: usize,
    ) -> Result<(Vec<Vec<i64>>, Vec<usize>, Vec<bool>), DataError> {
        if self.images.is_empty() {
            return Err(DataError::EmptyDataset { name: self.name.clone() });
        }
        if start >= self.len() {
            return Err(DataError::BatchOutOfRange { start, batch, len: self.len() });
        }
        let real = batch.min(self.len() - start);
        let (mut cols, labels) = self.minibatch(start, real, features)?;
        for col in &mut cols {
            col.resize(batch, 0);
        }
        let occupied: Vec<bool> = (0..batch).map(|b| b < real).collect();
        Ok((cols, labels, occupied))
    }
}

/// Load MNIST from IDX files if present, else synthesize.
pub fn mnist(train: bool, count: usize, seed: u64) -> Dataset {
    let (img, lab) = if train {
        ("data/train-images-idx3-ubyte", "data/train-labels-idx1-ubyte")
    } else {
        ("data/t10k-images-idx3-ubyte", "data/t10k-labels-idx1-ubyte")
    };
    if Path::new(img).exists() && Path::new(lab).exists() {
        if let Ok(ds) = load_idx(img, lab, count) {
            return ds;
        }
    }
    synthetic_digits(count, seed, "mnist-synth")
}

fn load_idx(img_path: &str, lab_path: &str, count: usize) -> anyhow::Result<Dataset> {
    let mut img = Vec::new();
    std::fs::File::open(img_path)?.read_to_end(&mut img)?;
    let mut lab = Vec::new();
    std::fs::File::open(lab_path)?.read_to_end(&mut lab)?;
    anyhow::ensure!(u32::from_be_bytes(img[0..4].try_into()?) == 2051, "bad image magic");
    anyhow::ensure!(u32::from_be_bytes(lab[0..4].try_into()?) == 2049, "bad label magic");
    let n = (u32::from_be_bytes(img[4..8].try_into()?) as usize).min(count);
    let h = u32::from_be_bytes(img[8..12].try_into()?) as usize;
    let w = u32::from_be_bytes(img[12..16].try_into()?) as usize;
    let images = (0..n)
        .map(|i| img[16 + i * h * w..16 + (i + 1) * h * w].iter().map(|&b| b as f32 / 255.0).collect())
        .collect();
    let labels = (0..n).map(|i| lab[8 + i] as usize).collect();
    Ok(Dataset { shape: (1, h, w), images, labels, classes: 10, name: "mnist".into() })
}

/// Synthetic digit-like dataset: per-class stroke templates + jitter.
pub fn synthetic_digits(count: usize, seed: u64, name: &str) -> Dataset {
    synthetic(count, seed, 10, (1, 28, 28), 0.0, name)
}

/// Synthetic Skin-Cancer-MNIST stand-in: 7 classes, 28×28×3, blob textures.
pub fn synthetic_cancer(count: usize, seed: u64) -> Dataset {
    synthetic(count, seed, 7, (3, 28, 28), 0.35, "cancer-synth")
}

/// Synthetic SVHN-like source set: the same digit templates as
/// `synthetic_digits` (both are digit corpora!) rendered in a different
/// "domain" (instance jitter/noise distribution) — the realistic analogue
/// of SVHN→MNIST transfer where low-level features carry over.
pub fn synthetic_svhn(count: usize, seed: u64) -> Dataset {
    synthetic(count, seed ^ 0x5711, 10, (1, 28, 28), 0.0, "svhn-synth")
}

/// Synthetic CIFAR-like source set (3 channels, shares blob structure with
/// the cancer stand-in).
pub fn synthetic_cifar(count: usize, seed: u64) -> Dataset {
    synthetic(count, seed ^ 0xc1fa, 10, (3, 28, 28), 0.35, "cifar-synth")
}

/// Class-conditional generator: a fixed per-class template (low-frequency
/// blobs + one or two "strokes"), instance jitter, optional style shift
/// (`style` rotates the template mix so source/target pairs differ but
/// share edges/blobs — the features conv layers learn).
fn synthetic(
    count: usize,
    seed: u64,
    classes: usize,
    shape: (usize, usize, usize),
    style: f32,
    name: &str,
) -> Dataset {
    let (c, h, w) = shape;
    // class templates from a seed that does NOT depend on `count`, so train
    // and test splits see the same classes.
    let mut trng = GlyphRng::new(0x7ee7_u64 ^ classes as u64 ^ ((style * 100.0) as u64) << 8);
    let templates: Vec<Vec<f32>> = (0..classes)
        .map(|k| {
            let mut t = vec![0f32; c * h * w];
            // 3 gaussian blobs per class at class-dependent positions
            for b in 0..3 {
                let cx = ((trng.uniform_mod(w as u64 - 8) + 4) as f32) + style * (k as f32 % 3.0);
                let cy = ((trng.uniform_mod(h as u64 - 8) + 4) as f32) + style * ((k / 3) as f32);
                let sg = 2.0 + (b as f32) + 0.5 * (k % 2) as f32;
                for ch in 0..c {
                    let gain = 1.0 / (1.0 + 0.6 * ((ch + b + k) % 3) as f32);
                    for y in 0..h {
                        for x in 0..w {
                            let d2 = (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2);
                            t[(ch * h + y) * w + x] += gain * (-d2 / (2.0 * sg * sg)).exp();
                        }
                    }
                }
            }
            // normalize to [0,1]
            let m = t.iter().cloned().fold(0f32, f32::max).max(1e-6);
            t.iter_mut().for_each(|v| *v /= m);
            t
        })
        .collect();
    let mut rng = GlyphRng::new(seed);
    let mut images = Vec::with_capacity(count);
    let mut labels = Vec::with_capacity(count);
    for i in 0..count {
        let k = i % classes;
        let (dx, dy) = ((rng.uniform_mod(5) as isize) - 2, (rng.uniform_mod(5) as isize) - 2);
        let mut img = vec![0f32; c * h * w];
        for ch in 0..c {
            for y in 0..h {
                for x in 0..w {
                    let sx = x as isize - dx;
                    let sy = y as isize - dy;
                    let v = if sx >= 0 && sx < w as isize && sy >= 0 && sy < h as isize {
                        templates[k][(ch * h + sy as usize) * w + sx as usize]
                    } else {
                        0.0
                    };
                    let noise = (rng.uniform_f64() as f32 - 0.5) * 0.15;
                    img[(ch * h + y) * w + x] = (v + noise).clamp(0.0, 1.0);
                }
            }
        }
        images.push(img);
        labels.push(k);
    }
    Dataset { shape, images, labels, classes, name: name.into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_is_deterministic_and_shaped() {
        let a = synthetic_digits(20, 1, "t");
        let b = synthetic_digits(20, 1, "t");
        assert_eq!(a.images, b.images);
        assert_eq!(a.shape, (1, 28, 28));
        assert_eq!(a.images[0].len(), 28 * 28);
        assert!(a.images[0].iter().all(|&p| (0.0..=1.0).contains(&p)));
        assert_eq!(a.labels[3], 3);
    }

    #[test]
    fn classes_are_distinguishable() {
        // a nearest-template classifier must beat chance comfortably —
        // otherwise the accuracy experiments are meaningless.
        let train = synthetic_digits(50, 2, "t");
        let test = synthetic_digits(40, 99, "t");
        let mut correct = 0;
        for i in 0..test.len() {
            let mut best = (f32::MAX, 0usize);
            for k in 0..10 {
                // use train sample of class k as prototype
                let proto = &train.images[k];
                let d: f32 = proto.iter().zip(&test.images[i]).map(|(a, b)| (a - b).powi(2)).sum();
                if d < best.0 {
                    best = (d, k);
                }
            }
            if best.1 == test.labels[i] {
                correct += 1;
            }
        }
        assert!(correct as f64 / test.len() as f64 > 0.5, "acc {}", correct as f64 / test.len() as f64);
    }

    #[test]
    fn cancer_and_sources_have_right_shapes() {
        assert_eq!(synthetic_cancer(7, 1).shape, (3, 28, 28));
        assert_eq!(synthetic_cancer(7, 1).classes, 7);
        assert_eq!(synthetic_svhn(5, 1).shape, (1, 28, 28));
        assert_eq!(synthetic_cifar(5, 1).shape, (3, 28, 28));
    }

    #[test]
    fn image_i8_quantization() {
        let ds = synthetic_digits(2, 3, "t");
        let q = ds.image_i8(0);
        assert!(q.iter().all(|&v| (0..=127).contains(&v)));
    }

    #[test]
    fn out_of_range_sample_is_a_descriptive_error() {
        let ds = synthetic_digits(2, 3, "t");
        let err = ds.try_image_i8(7).err().expect("must reject");
        assert_eq!(err, DataError::SampleOutOfRange { index: 7, len: 2 });
        let msg = err.to_string();
        assert!(msg.contains('7') && msg.contains('2'), "{msg}");
    }

    #[test]
    fn empty_dataset_is_its_own_error() {
        let ds = Dataset {
            shape: (1, 28, 28),
            images: vec![],
            labels: vec![],
            classes: 10,
            name: "empty".into(),
        };
        assert_eq!(ds.try_image_i8(0), Err(DataError::EmptyDataset { name: "empty".into() }));
        let err = ds.minibatch(0, 1, 4).err().expect("must reject");
        assert!(matches!(err, DataError::EmptyDataset { .. }), "{err}");
        assert!(err.to_string().contains("empty"));
    }

    #[test]
    fn minibatch_columns_and_bounds() {
        let ds = synthetic_digits(6, 3, "t");
        let (cols, labels) = ds.minibatch(2, 2, 4).unwrap();
        assert_eq!(cols.len(), 4);
        assert_eq!(cols[0].len(), 2);
        assert_eq!(labels, vec![2, 3]);
        // the even pixel sampling hits the first and last pixel
        assert_eq!(cols[0][0], ds.image_i8(2)[0]);
        assert_eq!(cols[3][0], ds.image_i8(2)[783]);
        let err = ds.minibatch(5, 2, 4).err().expect("must reject");
        assert_eq!(err, DataError::BatchOutOfRange { start: 5, batch: 2, len: 6 });
    }

    #[test]
    fn minibatch_padded_masks_the_ragged_tail() {
        let ds = synthetic_digits(6, 3, "t");
        // fully occupied window: identical to the strict loader, all-true mask
        let (cols, labels, occ) = ds.minibatch_padded(2, 2, 4).unwrap();
        let (strict_cols, strict_labels) = ds.minibatch(2, 2, 4).unwrap();
        assert_eq!(cols, strict_cols);
        assert_eq!(labels, strict_labels);
        assert_eq!(occ, vec![true, true]);

        // ragged tail: 2 real samples in a window of 4, vacant slots zeroed
        let (cols, labels, occ) = ds.minibatch_padded(4, 4, 4).unwrap();
        assert_eq!(occ, vec![true, true, false, false]);
        assert_eq!(labels, vec![4, 5]);
        for col in &cols {
            assert_eq!(col.len(), 4);
            assert_eq!(&col[2..], &[0, 0], "vacant slots must be zero");
        }
        assert_eq!(cols[0][0], ds.image_i8(4)[0]);

        // a window holding no real sample is an error, not an all-vacant batch
        let err = ds.minibatch_padded(6, 4, 4).err().expect("must reject");
        assert_eq!(err, DataError::BatchOutOfRange { start: 6, batch: 4, len: 6 });
    }
}
