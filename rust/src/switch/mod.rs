//! The cryptosystem switch between BGV and TFHE — the paper's §4.2.
//!
//! * [`extract`] — BGV→TFHE: the Δ scalar map (Chimera Lemma-1 analogue,
//!   exact here because q ≡ 1 mod t), `SampleExtract` of each batch lane,
//!   LWE modulus switch q → 2^32 and key switch onto the TFHE key, then
//!   8-bit digit extraction by programmable bootstrapping.
//! * [`repack`] — TFHE→BGV: weighted gate-bootstrap outputs recomposed by
//!   plain LWE addition (Theorem-3 step ➊: outputs restricted to the 2^24
//!   grid = multiples of p^{−r}), the packing functional key switch placing
//!   lane b at coefficient X^b under the BGV ring key, and the modulus
//!   raise to q with the −t MSB→LSB map, performed by the refresh authority
//!   (the documented bootstrapping substitution, DESIGN.md §5).
//!
//! Values crossing the switch are 8-bit signed fixed-point (the paper's
//! quantization); the bits delivered to Algorithms 1–2 are two's-complement,
//! MSB (sign) first.

pub mod extract;
pub mod repack;

pub use extract::{strided_positions, LweExtractor};
pub use repack::{interleaved_positions, Repacker};

/// Historical names of the switch engines (PR ≤ 3 call sites / examples).
pub type BgvToTfheSwitch = LweExtractor;
pub type TfheToBgvSwitch = Repacker;

/// Bit width of values crossing the switch (paper: 8-bit quantization).
pub const SWITCH_BITS: u32 = 8;

/// Torus position of the value LSB: values live at `v · 2^VALUE_POS` on the
/// torus, v an 8-bit two's-complement integer.
pub const VALUE_POS: u32 = 32 - SWITCH_BITS;

/// Switch-layer validation failure: every public extract entry point checks
/// its coefficient positions against the ciphertext's slot count up front
/// and reports *which* index overflowed instead of panicking deep inside the
/// lane-extraction arithmetic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SwitchError {
    /// A requested coefficient position does not exist in the ring.
    PositionOutOfRange {
        /// The offending coefficient index.
        position: usize,
        /// The ciphertext's slot (ring-degree) count.
        slots: usize,
    },
}

impl std::fmt::Display for SwitchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SwitchError::PositionOutOfRange { position, slots } => write!(
                f,
                "switch position {position} out of range: the ciphertext has {slots} \
                 coefficient slots (valid positions are 0..{slots})"
            ),
        }
    }
}

impl std::error::Error for SwitchError {}

/// Per-worker scratch for the scheme-switch hot paths, mirroring the PR 1
/// `PbsScratch` / PR 3 `BgvScratch` design: one of these lives in every
/// `GlyphPool` [`crate::coordinator::executor::WorkerScratch`], so batched
/// switch fan-outs reuse warm buffers instead of allocating per lane
/// (`tests/zero_alloc_switch.rs`).
pub struct SwitchScratch {
    /// Dim-N_bgv extracted-sample workspace (`SampleExtract` output before
    /// the LWE key switch), grown on first use per dimension.
    pub lwe_n: crate::tfhe::LweCiphertext,
    /// Packing-key-switch accumulators (TFHE→BGV repack).
    pub repack: crate::tfhe::RepackScratch,
}

impl SwitchScratch {
    pub fn new() -> Self {
        SwitchScratch {
            lwe_n: crate::tfhe::LweCiphertext { a: Vec::new(), b: 0 },
            repack: crate::tfhe::RepackScratch::new(),
        }
    }

    /// The dim-`n` extraction workspace, resized on first use.
    pub fn lwe_n(&mut self, n: usize) -> &mut crate::tfhe::LweCiphertext {
        if self.lwe_n.a.len() != n {
            self.lwe_n.a.resize(n, 0);
        }
        &mut self.lwe_n
    }
}

impl Default for SwitchScratch {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::extract::LweExtractor as BgvToTfheSwitch;
    use super::repack::Repacker as TfheToBgvSwitch;
    use crate::bgv::{BgvContext, BgvParams, BgvSecretKey, KeyAuthority, NoiseRefresher, Plaintext};
    use crate::math::rng::GlyphRng;
    use crate::tfhe::{LweKey, TfheCloudKey, TfheParams, TrlweKey};
    use std::sync::Arc;

    pub(crate) struct SwitchFixture {
        pub bgv_ctx: Arc<BgvContext>,
        pub bgv_sk: Arc<BgvSecretKey>,
        pub lwe_key: LweKey,
        pub gate_ck: TfheCloudKey,
        pub extract_ck: TfheCloudKey,
        pub fwd: BgvToTfheSwitch,
        pub bwd: TfheToBgvSwitch,
        pub auth: Arc<KeyAuthority>,
        pub rng: GlyphRng,
    }

    pub(crate) fn fixture(seed: u64) -> SwitchFixture {
        let bgv_ctx = BgvContext::new(BgvParams::test_params());
        let mut rng = GlyphRng::new(seed);
        let bgv_sk = Arc::new(BgvSecretKey::generate(&bgv_ctx, &mut rng));
        let params = TfheParams::test_params();
        let lwe_key = LweKey::generate_binary(params.n, &mut rng);
        let trlwe_key = TrlweKey::generate(params.big_n, &mut rng);
        let gate_ck = TfheCloudKey::generate(&lwe_key, &trlwe_key, &params, &mut rng);
        let ext_params = TfheParams::test_extract_params();
        let ext_ring = TrlweKey::generate(ext_params.big_n, &mut rng);
        let extract_ck = TfheCloudKey::generate(&lwe_key, &ext_ring, &ext_params, &mut rng);
        let fwd = BgvToTfheSwitch::generate(&bgv_sk, &lwe_key, &params, &mut rng);
        let bwd = TfheToBgvSwitch::generate(&trlwe_key, &bgv_sk, &mut rng);
        let auth = KeyAuthority::new(bgv_sk.clone(), GlyphRng::new(seed + 1));
        SwitchFixture { bgv_ctx, bgv_sk, lwe_key, gate_ck, extract_ck, fwd, bwd, auth, rng }
    }

    #[test]
    fn full_round_trip_bgv_to_tfhe_to_bgv() {
        // Encrypt 8-bit values in BGV, switch to TFHE bits, recompose the
        // bits at their weighted positions (identity function), pack back to
        // BGV, and compare.
        let mut f = fixture(500);
        let values: Vec<i64> = vec![0, 1, -1, 42, -42, 100, -128, 127];
        // Scale values to the top 8 bits of the plaintext ring: t = 2^16 in
        // the test profile, so the switch sees v·2^8 (frac_bits = 8).
        let frac = f.bgv_ctx.params.t.trailing_zeros() - super::SWITCH_BITS;
        let scaled: Vec<i64> = values.iter().map(|&v| v << frac).collect();
        let pt = Plaintext::encode_batch(&scaled, &f.bgv_ctx.params);
        let ct = f.bgv_sk.encrypt(&pt, &mut f.rng);

        let lanes = values.len();
        let bits = f.fwd.to_bits(&ct, lanes, &f.extract_ck).unwrap();
        assert_eq!(bits.len(), lanes);
        assert_eq!(bits[0].len(), super::SWITCH_BITS as usize);

        // Identity recomposition: AND each bit with an encrypted TRUE at its
        // weighted output position.
        let t_enc = crate::tfhe::encode_bit(true);
        let truth =
            crate::tfhe::LweCiphertext::encrypt(t_enc, &f.lwe_key, f.gate_ck.params.alpha_lwe, &mut f.rng);
        let recomposed: Vec<crate::tfhe::LweCiphertext> = bits
            .iter()
            .map(|lane_bits| {
                let mut acc: Option<crate::tfhe::LweCiphertext> = None;
                for (i, b) in lane_bits.iter().enumerate() {
                    let pos = super::VALUE_POS + (super::SWITCH_BITS - 1 - i as u32);
                    let w = f.gate_ck.and_weighted_raw(b, &truth, pos);
                    match &mut acc {
                        None => acc = Some(w),
                        Some(a) => a.add_assign(&w),
                    }
                }
                acc.unwrap()
            })
            .collect();

        let out = f.bwd.pack_and_raise(&recomposed, &f.auth);
        let got = f.auth.sk.decrypt(&out).decode_batch(lanes);
        assert_eq!(got, values);
        assert_eq!(f.auth.refresh_count(), 1);
    }
}
