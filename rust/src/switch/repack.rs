//! TFHE → BGV: steps ➊–➌ of the paper's Figure 5 (right).
//!
//! ➊ The activation's gate bootstraps already emit every output bit at its
//!   weighted torus position `2^(24+i)` (gates::and_weighted_raw), so a
//!   plain LWE sum recomposes the 8-bit value on the exact 2^24 grid — the
//!   "functional gate bootstrapping restricted to multiples of p^{−r}".
//! ➋ The packing functional key switch places lane b's LWE at coefficient
//!   `X^b` of one ring ciphertext under the BGV secret's coefficients.
//! ➌ The modulus raise 2^32 → q with the −t MSB→LSB map is performed by the
//!   refresh authority (the documented substitute for the recryption HElib
//!   would run here, DESIGN.md §5): the packed torus ciphertext is opened on
//!   the 8-bit grid and re-encrypted as a fresh top-level BGV ciphertext.
//!
//! Since PR 4 the repacker is batch-parallel: [`Repacker::pack_and_raise_many`]
//! fans the packing key switches of a whole layer boundary across the
//! `GlyphPool` (each worker packing through its warm
//! [`crate::tfhe::RepackScratch`], zero allocations per lane in the scratch
//! path — `tests/zero_alloc_switch.rs`), then performs the modulus raises
//! *serially in submission order* so the refresh authority's RNG draws stay
//! deterministic — batched results are bit-identical to a per-group serial
//! loop. The raise's ring key is derived once at key generation instead of
//! per call.

use crate::bgv::{BgvCiphertext, BgvSecretKey, KeyAuthority, Plaintext};
use crate::coordinator::executor::GlyphPool;
use crate::math::rng::GlyphRng;
use crate::tfhe::keyswitch::PackingKeySwitchKey;
use crate::tfhe::{LweCiphertext, TrlweCiphertext, TrlweKey};

use super::VALUE_POS;

/// The TFHE→BGV repacking engine (key material for both steps).
pub struct Repacker {
    /// gate-profile extracted key (dim N_gate) → BGV ring key packing.
    pub pksk: PackingKeySwitchKey,
    /// The BGV secret's coefficient ring key, cached for the authority's
    /// modulus raise (built once here instead of per raised ciphertext).
    raise_ring: TrlweKey,
}

impl Repacker {
    /// `gate_ring` is the TRLWE key whose extracted key the activation
    /// outputs live under; the destination ring key is the BGV secret.
    pub fn generate(gate_ring: &TrlweKey, bgv_sk: &BgvSecretKey, rng: &mut GlyphRng) -> Self {
        let src = gate_ring.extracted_lwe_key();
        let dst_ring = TrlweKey::from_coeffs(bgv_sk.coeffs_i32());
        // base 4^7: decomposition remainder ≈ 2^4·||s||₁ ≈ 2^15 ≪ 2^23 grid margin.
        let pksk = PackingKeySwitchKey::generate(&src, &dst_ring, 4, 7, 1e-9, rng);
        Repacker { pksk, raise_ring: dst_ring }
    }

    /// Pack one recomposed LWE per batch lane into a single torus ring
    /// ciphertext under the BGV key (steps ➊–➋; all real lattice ops).
    pub fn pack<S: std::borrow::Borrow<LweCiphertext>>(&self, lanes: &[S]) -> TrlweCiphertext {
        let positions: Vec<usize> = (0..lanes.len()).collect();
        self.pack_at(lanes, &positions)
    }

    /// Pack at arbitrary coefficient positions (reverse packing for the
    /// backward pass's convolution-trick gradients).
    pub fn pack_at<S: std::borrow::Borrow<LweCiphertext>>(
        &self,
        lanes: &[S],
        positions: &[usize],
    ) -> TrlweCiphertext {
        self.pksk.pack(lanes, positions)
    }

    /// Pack at positions then raise via the authority, reading values back
    /// from those same positions into batch order. Generic over owned and
    /// borrowed lane slices so backend-polymorphic callers need no clones.
    pub fn pack_at_and_raise<S: std::borrow::Borrow<LweCiphertext>>(
        &self,
        lanes: &[S],
        positions: &[usize],
        auth: &KeyAuthority,
    ) -> BgvCiphertext {
        let packed = self.pack_at(lanes, positions);
        self.raise(&packed, positions, auth)
    }

    /// Steps ➊–➌: pack, then raise to a fresh BGV ciphertext via the
    /// refresh authority. Values are read on the 2^24 grid as signed 8-bit.
    pub fn pack_and_raise<S: std::borrow::Borrow<LweCiphertext>>(
        &self,
        lanes: &[S],
        auth: &KeyAuthority,
    ) -> BgvCiphertext {
        let positions: Vec<usize> = (0..lanes.len()).collect();
        self.pack_at_and_raise(lanes, &positions, auth)
    }

    /// Batched steps ➊–➌ over many lane groups (one packed ring ciphertext
    /// each): the packing key switches — the expensive lattice work — fan
    /// across the global [`GlyphPool`] with one warm
    /// [`crate::tfhe::RepackScratch`] per worker, then the modulus raises
    /// run serially in submission order (the authority's RNG draw order is
    /// part of the deterministic contract). Result `out[g]` is bit-identical
    /// to `pack_at_and_raise(groups[g].0, groups[g].1, auth)` run in a loop.
    pub fn pack_and_raise_many<S: std::borrow::Borrow<LweCiphertext> + Sync>(
        &self,
        groups: &[(&[S], &[usize])],
        auth: &KeyAuthority,
    ) -> Vec<BgvCiphertext> {
        let n = self.pksk.ring_n;
        let packed: Vec<TrlweCiphertext> =
            GlyphPool::global().map_with((0..groups.len()).collect(), |g, ws| {
                let (lanes, positions) = groups[g];
                let mut out = TrlweCiphertext::zero(n);
                self.pksk.pack_into(lanes, positions, &mut ws.switch.repack, &mut out);
                out
            });
        packed
            .iter()
            .zip(groups)
            .map(|(p, (_, positions))| self.raise(p, positions, auth))
            .collect()
    }

    /// The modulus raise performed by the refresh authority, reading the
    /// given coefficient positions against the cached ring key: each value
    /// is re-encoded at the *same* coefficient it was packed at, so
    /// reversed packing survives the raise.
    pub fn raise(
        &self,
        packed: &TrlweCiphertext,
        positions: &[usize],
        auth: &KeyAuthority,
    ) -> BgvCiphertext {
        raise_with_ring(packed, positions, &self.raise_ring, auth)
    }
}

/// The modulus raise: open the packed torus ciphertext on the 8-bit grid at
/// the given positions and re-encrypt at top level (counted as one refresh
/// for HOP accounting). [`Repacker::raise`] supplies the ring key cached at
/// key generation.
fn raise_with_ring(
    packed: &TrlweCiphertext,
    positions: &[usize],
    ring: &TrlweKey,
    auth: &KeyAuthority,
) -> BgvCiphertext {
    let phases = packed.phase(ring);
    let n = auth.ctx().params.n;
    let mut values = vec![0i64; n];
    for &p in positions {
        let ph = phases[p];
        let v = (ph.wrapping_add(1 << (VALUE_POS - 1)) >> VALUE_POS) & 0xFF;
        values[p] = if v >= 128 { v as i64 - 256 } else { v as i64 };
    }
    let pt = Plaintext::encode_batch(&values, &auth.ctx().params);
    // Charge the re-encryption through the refresh interface so the count
    // (and the cost model's recrypt charge) stays honest.
    let trivial = BgvCiphertext::trivial(&pt, auth.ctx(), auth.ctx().top_level());
    use crate::bgv::NoiseRefresher;
    auth.refresh(&trivial)
}

/// Interleave `batch` sample lanes under each anchor position: lane `b` of
/// anchor `a` sits at `a + b` (ascending) or at `a + batch−1−b`
/// (descending — reversed packing). The repacking position sets of a packed
/// (cross-sample SIMD) layout's TFHE→BGV boundary are built from this: the
/// anchors are the layout's feature-lane offsets, so one packing key switch
/// re-packs every sample of the mini-batch at once.
pub fn interleaved_positions(anchors: &[usize], batch: usize, descending: bool) -> Vec<usize> {
    let mut out = Vec::with_capacity(anchors.len() * batch);
    for &a in anchors {
        for b in 0..batch {
            out.push(if descending { a + batch - 1 - b } else { a + b });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::switch::tests::fixture;
    use crate::switch::VALUE_POS;

    #[test]
    fn interleaved_positions_fan_out_the_batch() {
        assert_eq!(interleaved_positions(&[0, 8], 3, false), vec![0, 1, 2, 8, 9, 10]);
        assert_eq!(interleaved_positions(&[8, 0], 3, true), vec![10, 9, 8, 2, 1, 0]);
        assert!(interleaved_positions(&[], 4, false).is_empty());
    }

    #[test]
    fn pack_places_lane_values() {
        let f = fixture(600);
        // Trivial LWEs (a = 0) at v·2^24 exercise the packing path without
        // needing the gate ring's secret (they are valid under any key).
        let values: Vec<i64> = vec![1, -2, 100, -100];
        let lwes: Vec<crate::tfhe::LweCiphertext> = values
            .iter()
            .map(|&v| {
                crate::tfhe::LweCiphertext::trivial(((v as i64) << VALUE_POS) as u32, f.bwd.pksk.pk.len())
            })
            .collect();
        let packed = f.bwd.pack(&lwes);
        let ring = TrlweKey::from_coeffs(f.bgv_sk.coeffs_i32());
        let phases = packed.phase(&ring);
        for (i, &v) in values.iter().enumerate() {
            let want = ((v as i64) << VALUE_POS) as u32;
            let d = phases[i].wrapping_sub(want);
            let dist = d.min(d.wrapping_neg());
            assert!(dist < 1 << 22, "lane {i}: {:#x} vs {want:#x}", phases[i]);
        }
    }

    #[test]
    fn pack_and_raise_delivers_fresh_bgv() {
        let f = fixture(601);
        let values: Vec<i64> = vec![7, -8, 127, -128, 0];
        let lwes: Vec<crate::tfhe::LweCiphertext> = values
            .iter()
            .map(|&v| {
                crate::tfhe::LweCiphertext::trivial(((v as i64) << VALUE_POS) as u32, f.bwd.pksk.pk.len())
            })
            .collect();
        let ct = f.bwd.pack_and_raise(&lwes, &f.auth);
        assert_eq!(ct.level, f.bgv_ctx.top_level());
        assert_eq!(f.bgv_sk.decrypt(&ct).decode_batch(values.len()), values);
        // fresh noise
        assert!(f.bgv_sk.noise_magnitude(&ct) < (f.bgv_ctx.params.t as i128) << 20);
    }

    #[test]
    fn pack_and_raise_many_matches_per_group_loop() {
        let f = fixture(602);
        let dim = f.bwd.pksk.pk.len();
        let mk = |vals: &[i64]| -> Vec<crate::tfhe::LweCiphertext> {
            vals.iter()
                .map(|&v| crate::tfhe::LweCiphertext::trivial(((v as i64) << VALUE_POS) as u32, dim))
                .collect()
        };
        let g0 = mk(&[3, -4, 55]);
        let g1 = mk(&[-100, 100]);
        let g2 = mk(&[0, 1, -1, 127]);
        let p0: Vec<usize> = vec![0, 1, 2];
        let p1: Vec<usize> = vec![5, 9];
        let p2: Vec<usize> = vec![3, 2, 1, 0];
        let groups: Vec<(&[crate::tfhe::LweCiphertext], &[usize])> =
            vec![(&g0[..], &p0[..]), (&g1[..], &p1[..]), (&g2[..], &p2[..])];
        let batched = f.bwd.pack_and_raise_many(&groups, &f.auth);
        assert_eq!(batched.len(), 3);
        // decryptions match a per-group serial loop (the raise re-encrypts,
        // so compare plaintexts, which the raise fixes exactly)
        let wants = [vec![3i64, -4, 55], vec![0, 0, 0, 0, 0, -100, 0, 0, 0, 100], vec![127, -1, 1, 0]];
        for (g, want) in batched.iter().zip(&wants) {
            assert_eq!(&f.bgv_sk.decrypt(g).decode_batch(want.len()), want);
        }
        assert_eq!(f.auth.refresh_count(), 3);
    }
}
