//! BGV → TFHE: steps ➊–➌ of the paper's Figure 5 (left), plus the 8-bit
//! digit extraction that feeds Algorithms 1–2.
//!
//! Pipeline per ciphertext (once) and per batch lane:
//!
//! 1. `×Δ` with Δ = (q−1)/t — the exact LSB→MSB module isomorphism
//!    (Chimera Lemma 1; noise maps to −e, it does not grow);
//! 2. `SampleExtract(lane b)` — an N-dimensional LWE mod q under the BGV
//!    secret's coefficient vector;
//! 3. LWE modulus switch q → 2^32 (RNS-to-torus rescale, exact integer
//!    arithmetic, error < L ulp);
//! 4. LWE key switch N → n onto the TFHE key (functional key-switching,
//!    Theorem 2 of Chimera as cited in the paper);
//! 5. digit extraction: bit k (MSB-first) = sign-PBS of `2^k · lwe`.
//!    Doubling discards already-decided top bits mod 1, so the extractions
//!    are independent — a boundary-noise flip costs at most 1 ulp of the
//!    8-bit quantization and cannot cascade.
//!
//! Since PR 4 the extractor is a batch-parallel engine in the PR 1/PR 3
//! mould: steps 2–4 are the allocation-free [`LweExtractor::extract_lane_into`]
//! (dim-N workspace and dim-n output come from the caller — the `GlyphPool`
//! workers hand in their warm [`crate::switch::SwitchScratch`] buffers), and
//! [`LweExtractor::to_bits_many_into`] fans *all* ciphertexts × lanes × bits
//! of a layer boundary across the pool in one `pbs_many` call. Every public
//! entry point validates its positions against the ring's slot count and
//! returns a [`SwitchError`] instead of panicking. The per-lane serial
//! reference ([`LweExtractor::to_bits_serial`]) is retained as the
//! bit-exactness oracle (`tests/switch_roundtrip.rs`,
//! `tests/train_step_golden.rs`).

use super::{SwitchError, SWITCH_BITS, VALUE_POS};
use crate::bgv::{BgvCiphertext, BgvSecretKey};
use crate::coordinator::executor::GlyphPool;
use crate::math::rng::GlyphRng;
use crate::tfhe::{LweCiphertext, LweKey, LweKeySwitchKey, TestPoly, TfheCloudKey, TfheParams, MU_BIT};

/// The BGV→TFHE extraction engine (key material + rescale precomputation).
pub struct LweExtractor {
    /// N_bgv (ternary BGV coefficients) → n (TFHE binary) at torus32.
    pub ksk: LweKeySwitchKey,
    /// Δ_ℓ per level (RNS residues).
    deltas: Vec<Vec<u64>>,
    /// RNS→torus rescale precomputation per level: for limb i at level ℓ,
    /// `(q_ℓ/q_i)^{-1} mod q_i`.
    qtilde: Vec<Vec<u64>>,
    /// Shoup companions `⌊q̃·2^64/q_i⌋` of [`Self::qtilde`] — the rescale
    /// multiply in the per-lane hot loop is a Shoup product, not a `u128 %`.
    qtilde_shoup: Vec<Vec<u64>>,
    primes: Vec<u64>,
}

impl LweExtractor {
    pub fn generate(
        bgv_sk: &BgvSecretKey,
        tfhe_key: &LweKey,
        params: &TfheParams,
        rng: &mut GlyphRng,
    ) -> Self {
        let src = LweKey::from_coeffs(bgv_sk.coeffs_i32());
        // base 4^7 = 2^28 decomposition: remainder error ≈ 2^3·||s||₁ ≈ 2^13.
        let ksk = LweKeySwitchKey::generate(&src, tfhe_key, 4, 7, params.alpha_lwe, rng);
        let ctx = &bgv_sk.ctx;
        let deltas = (1..=ctx.top_level()).map(|l| ctx.delta_rns(l)).collect();
        let qtilde: Vec<Vec<u64>> = (1..=ctx.top_level())
            .map(|l| {
                let rctx = ctx.ctx_at(l);
                (0..l).map(|i| rctx.q_over_qi_inv[i]).collect()
            })
            .collect();
        let qtilde_shoup = qtilde
            .iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .map(|(i, &qt)| crate::math::modarith::shoup_precompute(qt, ctx.params.primes[i]))
                    .collect()
            })
            .collect();
        LweExtractor { ksk, deltas, qtilde, qtilde_shoup, primes: ctx.params.primes.clone() }
    }

    /// Step 1, once per ciphertext: `×Δ` (LSB→MSB, exact, noise-preserving)
    /// and conversion to coefficient form, ready for per-lane extraction.
    pub fn prepare_msb(&self, ct: &BgvCiphertext) -> BgvCiphertext {
        self.prepare_msb_shifted(ct, 0)
    }

    /// [`Self::prepare_msb`] with the engine's quantization pre-shift folded
    /// into the same pass: ONE clone of the ciphertext per boundary crossing
    /// (the scalar multiplications are exact RNS residue products, so
    /// shift-then-Δ is bit-identical to scaling a separate copy first).
    pub fn prepare_msb_shifted(&self, ct: &BgvCiphertext, pre_shift: u32) -> BgvCiphertext {
        let mut c = ct.clone();
        if pre_shift > 0 {
            let res = c.c0.ctx.scalar_to_rns_i64(1i64 << pre_shift);
            c.rns_scalar_mul_assign(&res);
        }
        c.rns_scalar_mul_assign(&self.deltas[c.level - 1]);
        c.c0.to_coeff();
        c.c1.to_coeff();
        c
    }

    /// Steps 2–4 for one lane of a [`Self::prepare_msb`]'d ciphertext,
    /// allocation-free: `SampleExtract(lane)` into the warm dim-N workspace
    /// `lwe_n`, RNS→torus rescale, then the LWE key switch into the warm
    /// dim-n output `out` (`out.a.len()` must equal the TFHE key dimension).
    /// Bit-identical to the allocating reference path; zero heap traffic
    /// per lane (`tests/zero_alloc_switch.rs`).
    ///
    /// The RNS→torus rescale uses `x/q mod 1 = Σ_i [x_i·q̃_i]_{q_i}/q_i mod 1`
    /// with exact u128 division per limb (≤ 1 ulp per limb).
    pub fn extract_lane_into(
        &self,
        prepared: &BgvCiphertext,
        lane: usize,
        lwe_n: &mut LweCiphertext,
        out: &mut LweCiphertext,
    ) {
        let level = prepared.level;
        let n = prepared.c0.n();
        debug_assert!(lane < n, "validated by the public entry points");
        debug_assert_eq!(lwe_n.a.len(), n, "warm dim-N workspace required");
        let c0 = &prepared.c0.res;
        let c1 = &prepared.c1.res;
        let to_torus = |res: &dyn Fn(usize) -> u64| -> u32 {
            let mut acc = 0u64; // torus32 with 32 fractional bits, wrapping
            for i in 0..level {
                let qi = self.primes[i];
                let xi = res(i);
                // Shoup product with the precomputed q̃ companion — same
                // canonical value the old `mul_mod` (u128 %) produced.
                let y = crate::math::modarith::mul_shoup(
                    xi,
                    self.qtilde[level - 1][i],
                    self.qtilde_shoup[level - 1][i],
                    qi,
                );
                // (y << 32) / qi, rounded
                let term = (((y as u128) << 32) + (qi as u128 / 2)) / qi as u128;
                acc = acc.wrapping_add(term as u64);
            }
            acc as u32
        };
        // b-coefficient of the LWE = c0[lane]
        lwe_n.b = to_torus(&|i| c0[i][lane]);
        // a_j = −c1[lane−j] for j ≤ lane, +c1[N+lane−j] for j > lane
        for j in 0..n {
            lwe_n.a[j] = if j <= lane {
                to_torus(&|i| c1[i][lane - j]).wrapping_neg()
            } else {
                to_torus(&|i| c1[i][n + lane - j])
            };
        }
        self.ksk.switch_into(lwe_n, out);
    }

    /// Switch `lanes` batch lanes of a BGV ciphertext onto the TFHE key.
    /// The ciphertext's plaintext must hold values `v·2^frac` with `v` the
    /// 8-bit quantity to deliver (`frac = log2 t − 8`); the sub-quantization
    /// bits ride along as the SWALP rounding residue.
    ///
    /// Returns one torus32 LWE per lane with phase `v·2^24 + junk`.
    pub fn to_torus_lanes(
        &self,
        ct: &BgvCiphertext,
        lanes: usize,
    ) -> Result<Vec<LweCiphertext>, SwitchError> {
        let positions: Vec<usize> = (0..lanes).collect();
        self.to_torus_positions(ct, &positions)
    }

    /// Same, for arbitrary coefficient positions (reverse-packed backward
    /// tensors and the convolution-trick gradient coefficient use this).
    pub fn to_torus_positions(
        &self,
        ct: &BgvCiphertext,
        positions: &[usize],
    ) -> Result<Vec<LweCiphertext>, SwitchError> {
        self.to_torus_many(&[ct], positions)
    }

    /// Batched lane extraction: every `(ciphertext, position)` pair is
    /// independent work — the whole batch fans across the global
    /// [`GlyphPool`] in ONE call (ct-major, then position order), each
    /// worker extracting through its warm `SwitchScratch` buffers. The Δ
    /// map runs once per ciphertext, amortized over its lanes.
    pub fn to_torus_many(
        &self,
        cts: &[&BgvCiphertext],
        positions: &[usize],
    ) -> Result<Vec<LweCiphertext>, SwitchError> {
        self.to_torus_many_shifted(cts, positions, 0)
    }

    /// [`Self::to_torus_many`] with the quantization pre-shift folded into
    /// the per-ciphertext prepare pass (one clone per ciphertext total).
    pub fn to_torus_many_shifted(
        &self,
        cts: &[&BgvCiphertext],
        positions: &[usize],
        pre_shift: u32,
    ) -> Result<Vec<LweCiphertext>, SwitchError> {
        let prepared: Vec<BgvCiphertext> = cts
            .iter()
            .map(|ct| {
                self.validate_positions(ct, positions)?;
                Ok(self.prepare_msb_shifted(ct, pre_shift))
            })
            .collect::<Result<_, SwitchError>>()?;
        let dst = self.ksk.dst_dim;
        let jobs: Vec<(usize, usize)> = (0..prepared.len())
            .flat_map(|c| positions.iter().map(move |&p| (c, p)))
            .collect();
        Ok(GlyphPool::global().map_with(jobs, |(c, lane), ws| {
            let mut out = LweCiphertext::trivial(0, dst);
            let n = prepared[c].c0.n();
            // split borrow: the workspace comes from the worker scratch,
            // only the returned ciphertext is allocated per lane
            let scratch = ws.switch.lwe_n(n);
            self.extract_lane_into(&prepared[c], lane, scratch, &mut out);
            out
        }))
    }

    fn validate_positions(
        &self,
        ct: &BgvCiphertext,
        positions: &[usize],
    ) -> Result<(), SwitchError> {
        let slots = ct.c0.n();
        match positions.iter().find(|&&p| p >= slots) {
            Some(&position) => Err(SwitchError::PositionOutOfRange { position, slots }),
            None => Ok(()),
        }
    }

    /// Full BGV→TFHE switch: per lane, the 8 two's-complement bits
    /// (MSB/sign first) of the quantized value, as gate-ready ciphertexts.
    ///
    /// `ck` provides the bootstrapping for the digit extraction (one
    /// sign-PBS per bit).
    pub fn to_bits(
        &self,
        ct: &BgvCiphertext,
        lanes: usize,
        ck: &TfheCloudKey,
    ) -> Result<Vec<Vec<LweCiphertext>>, SwitchError> {
        let positions: Vec<usize> = (0..lanes).collect();
        self.to_bits_positions(ct, &positions, ck)
    }

    /// [`Self::to_bits`] for arbitrary coefficient positions.
    pub fn to_bits_positions(
        &self,
        ct: &BgvCiphertext,
        positions: &[usize],
        ck: &TfheCloudKey,
    ) -> Result<Vec<Vec<LweCiphertext>>, SwitchError> {
        Ok(self.to_bits_many(&[ct], positions, ck, 0)?.pop().expect("one ciphertext in, one out"))
    }

    /// Batched digit extraction over many ciphertexts: result is
    /// `[ct][lane][bit]` (MSB first). All cts × lanes × [`SWITCH_BITS`]
    /// sign-PBS extractions are independent (doubling discards
    /// already-decided top bits — module docs step 5), so the whole layer
    /// boundary fans across the pool in ONE `pbs_many` call instead of a
    /// per-ciphertext / per-lane / per-bit loop.
    pub fn to_bits_many(
        &self,
        cts: &[&BgvCiphertext],
        positions: &[usize],
        ck: &TfheCloudKey,
        pre_shift: u32,
    ) -> Result<Vec<Vec<Vec<LweCiphertext>>>, SwitchError> {
        let mut flat = Vec::new();
        self.to_bits_many_into(cts, positions, ck, pre_shift, &mut flat)?;
        let per_lane = SWITCH_BITS as usize;
        let mut it = flat.into_iter();
        Ok((0..cts.len())
            .map(|_| {
                (0..positions.len()).map(|_| (&mut it).take(per_lane).collect()).collect()
            })
            .collect())
    }

    /// Flat-output core of [`Self::to_bits_many`]: `out` is cleared and
    /// refilled in ct-major, then lane, then bit (MSB-first) order. A caller
    /// that holds its buffer across calls reuses the flat `Vec`'s capacity;
    /// `to_bits_many` itself passes a fresh buffer and regroups, so use this
    /// entry point directly when the allocation profile matters.
    pub fn to_bits_many_into(
        &self,
        cts: &[&BgvCiphertext],
        positions: &[usize],
        ck: &TfheCloudKey,
        pre_shift: u32,
        out: &mut Vec<LweCiphertext>,
    ) -> Result<(), SwitchError> {
        out.clear();
        let tv = TestPoly::constant(ck.params.big_n, MU_BIT.wrapping_neg());
        let lwes = self.to_torus_many_shifted(cts, positions, pre_shift)?;
        let per_lane = SWITCH_BITS as usize;
        let mut scaled_all = Vec::with_capacity(lwes.len() * per_lane);
        for mut lwe in lwes {
            // Half-window guard: turns the floor quantization into
            // round-to-nearest and moves exact grid values off the PBS
            // decision boundaries (otherwise the LSB of an exact value
            // sits exactly on a sign boundary and flips with the noise).
            lwe.add_constant(1 << (VALUE_POS - 1));
            for k in 0..SWITCH_BITS {
                let mut scaled = lwe.clone();
                scaled.scalar_mul_assign(1 << k);
                scaled_all.push(scaled);
            }
        }
        // sign-PBS: phase in [0, 1/2) means top bit 0 → output must encode
        // FALSE; the constant −μ test polynomial yields −μ on the positive
        // half, +μ on the negative half = bit encoding of the top bit.
        out.extend(ck.pbs_many(scaled_all, &tv));
        Ok(())
    }

    /// Retained per-lane serial reference of [`Self::to_bits_positions`]:
    /// the same Δ map, extraction, key switch and sign-PBS sequence run
    /// one lane and one bit at a time with no pool fan-out. Bit-identical
    /// to the batched engine (every job is deterministic and independent) —
    /// the oracle `tests/train_step_golden.rs` and `benches/switch.rs`
    /// measure against.
    pub fn to_bits_serial(
        &self,
        ct: &BgvCiphertext,
        positions: &[usize],
        ck: &TfheCloudKey,
        pre_shift: u32,
    ) -> Result<Vec<Vec<LweCiphertext>>, SwitchError> {
        self.validate_positions(ct, positions)?;
        let prepared = self.prepare_msb_shifted(ct, pre_shift);
        let n = prepared.c0.n();
        let tv = TestPoly::constant(ck.params.big_n, MU_BIT.wrapping_neg());
        let mut lwe_n = LweCiphertext::trivial(0, n);
        Ok(positions
            .iter()
            .map(|&lane| {
                let mut lwe = LweCiphertext::trivial(0, self.ksk.dst_dim);
                self.extract_lane_into(&prepared, lane, &mut lwe_n, &mut lwe);
                lwe.add_constant(1 << (VALUE_POS - 1));
                (0..SWITCH_BITS)
                    .map(|k| {
                        let mut scaled = lwe.clone();
                        scaled.scalar_mul_assign(1 << k);
                        ck.pbs(&scaled, &tv)
                    })
                    .collect()
            })
            .collect())
    }
}

/// Reference decoding of the value the switch delivers (for tests and the
/// refresh authority): the top 8 bits of `m mod t`, round-to-nearest
/// (matching the half-window guard in `to_bits`), as two's complement.
pub fn quantize_plain(m: i64, t: u64) -> i64 {
    let frac = t.trailing_zeros() - SWITCH_BITS;
    let mu = (m.rem_euclid(t as i64)) as u64;
    let v = ((mu + (1 << (frac - 1))) >> frac) & 0xFF;
    if v >= 128 {
        v as i64 - 256
    } else {
        v as i64
    }
}

/// Torus position of bit `i` (MSB-first) of the 8-bit value.
pub fn bit_position(i: usize) -> u32 {
    VALUE_POS + (SWITCH_BITS - 1 - i as u32)
}

/// Positions `base + k·stride` for `k < count` — the extraction fan-out of
/// a packed (cross-sample SIMD) layout. One `to_bits_many` call over such a
/// set covers a whole packed block — e.g. every batch-summed weight
/// gradient of a `PackedLayout` block at `k·stride + batch−1` — so a single
/// BGV→TFHE switch serves every feature lane at a layer boundary.
pub fn strided_positions(base: usize, stride: usize, count: usize) -> Vec<usize> {
    (0..count).map(|k| base + k * stride).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bgv::Plaintext;
    use crate::switch::tests::fixture;
    use crate::tfhe::decode_bit;

    #[test]
    fn torus_lanes_carry_msb_value() {
        let mut f = fixture(501);
        let t = f.bgv_ctx.params.t;
        let frac = t.trailing_zeros() - SWITCH_BITS;
        let values: Vec<i64> = vec![3, -3, 77, -77, 127, -128];
        let scaled: Vec<i64> = values.iter().map(|&v| v << frac).collect();
        let pt = Plaintext::encode_batch(&scaled, &f.bgv_ctx.params);
        let ct = f.bgv_sk.encrypt(&pt, &mut f.rng);
        let lwes = f.fwd.to_torus_lanes(&ct, values.len()).unwrap();
        for (i, lwe) in lwes.iter().enumerate() {
            let phase = lwe.phase(&f.lwe_key);
            let want = ((values[i] as i64) << VALUE_POS) as u32; // v·2^24
            let d = phase.wrapping_sub(want);
            let dist = d.min(d.wrapping_neg());
            assert!(dist < 1 << 20, "lane {i}: phase={phase:#x} want={want:#x}");
        }
    }

    #[test]
    fn to_bits_gives_twos_complement_msb_first() {
        let mut f = fixture(502);
        let t = f.bgv_ctx.params.t;
        let frac = t.trailing_zeros() - SWITCH_BITS;
        let values: Vec<i64> = vec![5, -6, 100, -100];
        let scaled: Vec<i64> = values.iter().map(|&v| v << frac).collect();
        let pt = Plaintext::encode_batch(&scaled, &f.bgv_ctx.params);
        let ct = f.bgv_sk.encrypt(&pt, &mut f.rng);
        let bits = f.fwd.to_bits(&ct, values.len(), &f.extract_ck).unwrap();
        for (lane, lane_bits) in bits.iter().enumerate() {
            let byte = (values[lane] & 0xFF) as u8;
            for (i, bct) in lane_bits.iter().enumerate() {
                let want = (byte >> (7 - i)) & 1 == 1;
                let got = decode_bit(bct.phase(&f.lwe_key));
                assert_eq!(got, want, "lane {lane} bit {i} (value {})", values[lane]);
            }
        }
    }

    #[test]
    fn sub_quantization_bits_are_dropped() {
        // value·2^frac + residue must still deliver `value`.
        let mut f = fixture(503);
        let t = f.bgv_ctx.params.t;
        let frac = t.trailing_zeros() - SWITCH_BITS;
        let residue = (1i64 << frac) / 3; // well inside the window
        let values: Vec<i64> = vec![9, -9, 55];
        let scaled: Vec<i64> = values.iter().map(|&v| (v << frac) + residue).collect();
        let pt = Plaintext::encode_batch(&scaled, &f.bgv_ctx.params);
        let ct = f.bgv_sk.encrypt(&pt, &mut f.rng);
        let bits = f.fwd.to_bits(&ct, values.len(), &f.extract_ck).unwrap();
        for (lane, lane_bits) in bits.iter().enumerate() {
            let mut got = 0u8;
            for bct in lane_bits {
                got = (got << 1) | decode_bit(bct.phase(&f.lwe_key)) as u8;
            }
            assert_eq!(got as i8 as i64, values[lane], "lane {lane}");
        }
    }

    #[test]
    fn out_of_range_position_is_a_descriptive_error() {
        let mut f = fixture(504);
        let pt = Plaintext::encode_batch(&[1, 2], &f.bgv_ctx.params);
        let ct = f.bgv_sk.encrypt(&pt, &mut f.rng);
        let slots = f.bgv_ctx.params.n;
        let err = f.fwd.to_torus_positions(&ct, &[0, slots + 7]).err().expect("must reject");
        assert_eq!(err, SwitchError::PositionOutOfRange { position: slots + 7, slots });
        let msg = err.to_string();
        assert!(msg.contains(&(slots + 7).to_string()) && msg.contains(&slots.to_string()), "{msg}");
        // the bits entry point propagates the same error
        assert!(f.fwd.to_bits_positions(&ct, &[slots], &f.extract_ck).is_err());
        // serial reference agrees
        assert!(f.fwd.to_bits_serial(&ct, &[slots], &f.extract_ck, 0).is_err());
    }

    #[test]
    fn batched_bits_match_serial_reference_exactly() {
        // The pooled extract engine must produce the same *ciphertexts* as
        // the retained serial path — not merely the same decryptions.
        let mut f = fixture(505);
        let t = f.bgv_ctx.params.t;
        let frac = t.trailing_zeros() - SWITCH_BITS;
        let values: Vec<i64> = vec![12, -3, 90];
        let scaled: Vec<i64> = values.iter().map(|&v| v << frac).collect();
        let pt = Plaintext::encode_batch(&scaled, &f.bgv_ctx.params);
        let ct = f.bgv_sk.encrypt(&pt, &mut f.rng);
        let positions = [0usize, 1, 2];
        let batched = f.fwd.to_bits_positions(&ct, &positions, &f.extract_ck).unwrap();
        let serial = f.fwd.to_bits_serial(&ct, &positions, &f.extract_ck, 0).unwrap();
        for (lane, (b, s)) in batched.iter().zip(&serial).enumerate() {
            for (bit, (cb, cs)) in b.iter().zip(s).enumerate() {
                assert_eq!(cb.a, cs.a, "lane {lane} bit {bit} mask");
                assert_eq!(cb.b, cs.b, "lane {lane} bit {bit} body");
            }
        }
    }

    #[test]
    fn strided_positions_cover_a_packed_block() {
        assert_eq!(strided_positions(7, 16, 4), vec![7, 23, 39, 55]);
        assert_eq!(strided_positions(0, 1, 3), vec![0, 1, 2]);
        assert!(strided_positions(5, 16, 0).is_empty());
    }

    #[test]
    fn quantize_plain_reference() {
        let t = 1u64 << 16;
        assert_eq!(quantize_plain(0, t), 0);
        assert_eq!(quantize_plain(5 << 8, t), 5);
        assert_eq!(quantize_plain(-(5i64 << 8), t), -5);
        assert_eq!(quantize_plain((5 << 8) + 100, t), 5); // rounds down
        assert_eq!(quantize_plain((5 << 8) + 200, t), 6); // rounds up
        assert_eq!(quantize_plain(127 << 8, t), 127);
        assert_eq!(quantize_plain(-(128i64 << 8), t), -128);
    }
}
