//! BGV → TFHE: steps ➊–➌ of the paper's Figure 5 (left), plus the 8-bit
//! digit extraction that feeds Algorithms 1–2.
//!
//! Pipeline per ciphertext (once) and per batch lane:
//!
//! 1. `×Δ` with Δ = (q−1)/t — the exact LSB→MSB module isomorphism
//!    (Chimera Lemma 1; noise maps to −e, it does not grow);
//! 2. `SampleExtract(lane b)` — an N-dimensional LWE mod q under the BGV
//!    secret's coefficient vector;
//! 3. LWE modulus switch q → 2^32 (RNS-to-torus rescale, exact integer
//!    arithmetic, error < L ulp);
//! 4. LWE key switch N → n onto the TFHE key (functional key-switching,
//!    Theorem 2 of Chimera as cited in the paper);
//! 5. digit extraction: bit k (MSB-first) = sign-PBS of `2^k · lwe`.
//!    Doubling discards already-decided top bits mod 1, so the extractions
//!    are independent — a boundary-noise flip costs at most 1 ulp of the
//!    8-bit quantization and cannot cascade.

use super::{SWITCH_BITS, VALUE_POS};
use crate::bgv::{BgvCiphertext, BgvSecretKey};
use crate::math::rng::GlyphRng;
use crate::tfhe::{LweCiphertext, LweKey, LweKeySwitchKey, TestPoly, TfheCloudKey, TfheParams, MU_BIT};

/// Key material for the BGV→TFHE direction.
pub struct BgvToTfheSwitch {
    /// N_bgv (ternary BGV coefficients) → n (TFHE binary) at torus32.
    pub ksk: LweKeySwitchKey,
    /// Δ_ℓ per level (RNS residues).
    deltas: Vec<Vec<u64>>,
    /// RNS→torus rescale precomputation per level: for limb i at level ℓ,
    /// `(q_ℓ/q_i)^{-1} mod q_i`.
    qtilde: Vec<Vec<u64>>,
    primes: Vec<u64>,
}

impl BgvToTfheSwitch {
    pub fn generate(
        bgv_sk: &BgvSecretKey,
        tfhe_key: &LweKey,
        params: &TfheParams,
        rng: &mut GlyphRng,
    ) -> Self {
        let src = LweKey::from_coeffs(bgv_sk.coeffs_i32());
        // base 4^7 = 2^28 decomposition: remainder error ≈ 2^3·||s||₁ ≈ 2^13.
        let ksk = LweKeySwitchKey::generate(&src, tfhe_key, 4, 7, params.alpha_lwe, rng);
        let ctx = &bgv_sk.ctx;
        let deltas = (1..=ctx.top_level()).map(|l| ctx.delta_rns(l)).collect();
        let qtilde = (1..=ctx.top_level())
            .map(|l| {
                let rctx = ctx.ctx_at(l);
                (0..l).map(|i| rctx.q_over_qi_inv[i]).collect()
            })
            .collect();
        BgvToTfheSwitch { ksk, deltas, qtilde, primes: ctx.params.primes.clone() }
    }

    /// Extract lane `b` of an MSB-mapped ciphertext as a torus32 LWE under
    /// the BGV coefficient key.
    ///
    /// The RNS→torus rescale uses `x/q mod 1 = Σ_i [x_i·q̃_i]_{q_i}/q_i mod 1`
    /// with exact u128 division per limb (≤ 1 ulp per limb).
    fn extract_lane_torus32(&self, c0: &[Vec<u64>], c1: &[Vec<u64>], level: usize, lane: usize, n: usize) -> LweCiphertext {
        let to_torus = |res: &dyn Fn(usize) -> u64| -> u32 {
            let mut acc = 0u64; // torus32 with 32 fractional bits, wrapping
            for i in 0..level {
                let qi = self.primes[i];
                let xi = res(i);
                let y = crate::math::modarith::mul_mod(xi, self.qtilde[level - 1][i], qi);
                // (y << 32) / qi, rounded
                let term = (((y as u128) << 32) + (qi as u128 / 2)) / qi as u128;
                acc = acc.wrapping_add(term as u64);
            }
            acc as u32
        };
        // b-coefficient of the LWE = c0[lane]
        let b = to_torus(&|i| c0[i][lane]);
        // a_j = −c1[lane−j] for j ≤ lane, +c1[N+lane−j] for j > lane
        let a: Vec<u32> = (0..n)
            .map(|j| {
                if j <= lane {
                    let v = to_torus(&|i| c1[i][lane - j]);
                    v.wrapping_neg()
                } else {
                    to_torus(&|i| c1[i][n + lane - j])
                }
            })
            .collect();
        LweCiphertext { a, b }
    }

    /// Switch `lanes` batch lanes of a BGV ciphertext onto the TFHE key.
    /// The ciphertext's plaintext must hold values `v·2^frac` with `v` the
    /// 8-bit quantity to deliver (`frac = log2 t − 8`); the sub-quantization
    /// bits ride along as the SWALP rounding residue.
    ///
    /// Returns one torus32 LWE per lane with phase `v·2^24 + junk`.
    pub fn to_torus_lanes(&self, ct: &BgvCiphertext, lanes: usize) -> Vec<LweCiphertext> {
        let positions: Vec<usize> = (0..lanes).collect();
        self.to_torus_positions(ct, &positions)
    }

    /// Same, for arbitrary coefficient positions (reverse-packed backward
    /// tensors and the convolution-trick gradient coefficient use this).
    ///
    /// The per-lane extract + key switch is independent work — it fans
    /// across the global `GlyphPool` (order-preserving).
    pub fn to_torus_positions(&self, ct: &BgvCiphertext, positions: &[usize]) -> Vec<LweCiphertext> {
        let level = ct.level;
        // ×Δ : LSB→MSB (exact, noise-preserving)
        let mut c = ct.clone();
        c.rns_scalar_mul_assign(&self.deltas[level - 1]);
        c.c0.to_coeff();
        c.c1.to_coeff();
        let n = c.c0.n();
        let c0 = &c.c0.res;
        let c1 = &c.c1.res;
        crate::coordinator::executor::GlyphPool::global().map(positions.to_vec(), |lane| {
            let lwe_q = self.extract_lane_torus32(c0, c1, level, lane, n);
            self.ksk.switch(&lwe_q)
        })
    }

    /// Full BGV→TFHE switch: per lane, the 8 two's-complement bits
    /// (MSB/sign first) of the quantized value, as gate-ready ciphertexts.
    ///
    /// `ck` provides the bootstrapping for the digit extraction (one
    /// sign-PBS per bit).
    pub fn to_bits(&self, ct: &BgvCiphertext, lanes: usize, ck: &TfheCloudKey) -> Vec<Vec<LweCiphertext>> {
        let positions: Vec<usize> = (0..lanes).collect();
        self.to_bits_positions(ct, &positions, ck)
    }

    /// [`Self::to_bits`] for arbitrary coefficient positions.
    ///
    /// All lanes × [`SWITCH_BITS`] sign-PBS extractions are independent
    /// (doubling discards already-decided top bits — module docs step 5), so
    /// the whole batch fans across the pool in ONE `pbs_many` call instead
    /// of a sequential per-lane / per-bit loop.
    pub fn to_bits_positions(
        &self,
        ct: &BgvCiphertext,
        positions: &[usize],
        ck: &TfheCloudKey,
    ) -> Vec<Vec<LweCiphertext>> {
        let tv = TestPoly::constant(ck.params.big_n, MU_BIT.wrapping_neg());
        let per_lane = SWITCH_BITS as usize;
        let mut scaled_all = Vec::with_capacity(positions.len() * per_lane);
        for mut lwe in self.to_torus_positions(ct, positions) {
            // Half-window guard: turns the floor quantization into
            // round-to-nearest and moves exact grid values off the PBS
            // decision boundaries (otherwise the LSB of an exact value
            // sits exactly on a sign boundary and flips with the noise).
            lwe.add_constant(1 << (VALUE_POS - 1));
            for k in 0..SWITCH_BITS {
                let mut scaled = lwe.clone();
                scaled.scalar_mul_assign(1 << k);
                scaled_all.push(scaled);
            }
        }
        // sign-PBS: phase in [0, 1/2) means top bit 0 → output must encode
        // FALSE; the constant −μ test polynomial yields −μ on the positive
        // half, +μ on the negative half = bit encoding of the top bit.
        let bits = ck.pbs_many(scaled_all, &tv);
        let mut it = bits.into_iter();
        (0..positions.len()).map(|_| (&mut it).take(per_lane).collect()).collect()
    }
}

/// Reference decoding of the value the switch delivers (for tests and the
/// refresh authority): the top 8 bits of `m mod t`, round-to-nearest
/// (matching the half-window guard in `to_bits`), as two's complement.
pub fn quantize_plain(m: i64, t: u64) -> i64 {
    let frac = t.trailing_zeros() - SWITCH_BITS;
    let mu = (m.rem_euclid(t as i64)) as u64;
    let v = ((mu + (1 << (frac - 1))) >> frac) & 0xFF;
    if v >= 128 {
        v as i64 - 256
    } else {
        v as i64
    }
}

/// Torus position of bit `i` (MSB-first) of the 8-bit value.
pub fn bit_position(i: usize) -> u32 {
    VALUE_POS + (SWITCH_BITS - 1 - i as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bgv::Plaintext;
    use crate::switch::tests::fixture;
    use crate::tfhe::decode_bit;

    #[test]
    fn torus_lanes_carry_msb_value() {
        let mut f = fixture(501);
        let t = f.bgv_ctx.params.t;
        let frac = t.trailing_zeros() - SWITCH_BITS;
        let values: Vec<i64> = vec![3, -3, 77, -77, 127, -128];
        let scaled: Vec<i64> = values.iter().map(|&v| v << frac).collect();
        let pt = Plaintext::encode_batch(&scaled, &f.bgv_ctx.params);
        let ct = f.bgv_sk.encrypt(&pt, &mut f.rng);
        let lwes = f.fwd.to_torus_lanes(&ct, values.len());
        for (i, lwe) in lwes.iter().enumerate() {
            let phase = lwe.phase(&f.lwe_key);
            let want = ((values[i] as i64) << VALUE_POS) as u32; // v·2^24
            let d = phase.wrapping_sub(want);
            let dist = d.min(d.wrapping_neg());
            assert!(dist < 1 << 20, "lane {i}: phase={phase:#x} want={want:#x}");
        }
    }

    #[test]
    fn to_bits_gives_twos_complement_msb_first() {
        let mut f = fixture(502);
        let t = f.bgv_ctx.params.t;
        let frac = t.trailing_zeros() - SWITCH_BITS;
        let values: Vec<i64> = vec![5, -6, 100, -100];
        let scaled: Vec<i64> = values.iter().map(|&v| v << frac).collect();
        let pt = Plaintext::encode_batch(&scaled, &f.bgv_ctx.params);
        let ct = f.bgv_sk.encrypt(&pt, &mut f.rng);
        let bits = f.fwd.to_bits(&ct, values.len(), &f.extract_ck);
        for (lane, lane_bits) in bits.iter().enumerate() {
            let byte = (values[lane] & 0xFF) as u8;
            for (i, bct) in lane_bits.iter().enumerate() {
                let want = (byte >> (7 - i)) & 1 == 1;
                let got = decode_bit(bct.phase(&f.lwe_key));
                assert_eq!(got, want, "lane {lane} bit {i} (value {})", values[lane]);
            }
        }
    }

    #[test]
    fn sub_quantization_bits_are_dropped() {
        // value·2^frac + residue must still deliver `value`.
        let mut f = fixture(503);
        let t = f.bgv_ctx.params.t;
        let frac = t.trailing_zeros() - SWITCH_BITS;
        let residue = (1i64 << frac) / 3; // well inside the window
        let values: Vec<i64> = vec![9, -9, 55];
        let scaled: Vec<i64> = values.iter().map(|&v| (v << frac) + residue).collect();
        let pt = Plaintext::encode_batch(&scaled, &f.bgv_ctx.params);
        let ct = f.bgv_sk.encrypt(&pt, &mut f.rng);
        let bits = f.fwd.to_bits(&ct, values.len(), &f.extract_ck);
        for (lane, lane_bits) in bits.iter().enumerate() {
            let mut got = 0u8;
            for bct in lane_bits {
                got = (got << 1) | decode_bit(bct.phase(&f.lwe_key)) as u8;
            }
            assert_eq!(got as i8 as i64, values[lane], "lane {lane}");
        }
    }

    #[test]
    fn quantize_plain_reference() {
        let t = 1u64 << 16;
        assert_eq!(quantize_plain(0, t), 0);
        assert_eq!(quantize_plain(5 << 8, t), 5);
        assert_eq!(quantize_plain(-(5i64 << 8), t), -5);
        assert_eq!(quantize_plain((5 << 8) + 100, t), 5); // rounds down
        assert_eq!(quantize_plain((5 << 8) + 200, t), 6); // rounds up
        assert_eq!(quantize_plain(127 << 8, t), 127);
        assert_eq!(quantize_plain(-(128i64 << 8), t), -128);
    }
}
