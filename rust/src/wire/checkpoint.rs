//! [`Checkpoint`] — the durable unit of a training run.
//!
//! Every K steps the serve layer captures the complete mutable state of a
//! job: the trainable FC weight ciphertexts, the live op counters, the
//! epoch/step cursor, the wall-clock already spent, and (on FHE) the
//! client/authority RNG cursors whose draws the next minibatch encryptions
//! and noise refreshes will consume. Everything else — datasets, network
//! topology, frozen layers, initial weight draws — regenerates
//! deterministically from the job spec's seed, so it is *not* stored;
//! restore rebuilds the network from the spec and overwrites exactly the
//! state that training mutated. A hash of the compiled [`Plan`] binds the
//! checkpoint to its schedule: resuming under a different topology or a
//! drifted scheduler is refused instead of silently corrupting a model.

use super::{fnv1a64, get_nested, put_nested, WireCodec, WireError, WireReader, WireWriter};
use crate::coordinator::metrics::OpSnapshot;
use crate::coordinator::scheduler::Plan;
use crate::nn::backend::Ct;
use crate::nn::engine::{Backend, GlyphEngine};
use crate::nn::linear::Weight;
use crate::nn::network::Network;

/// One trainable FC layer's weight ciphertexts, keyed by network unit
/// index.
#[derive(Clone)]
pub struct LayerWeights {
    pub unit: usize,
    /// `rows[out][in]`, same geometry as `FcLayer::w`.
    pub rows: Vec<Vec<Ct>>,
}

/// Resumable training-run state. See the module docs for what is stored
/// vs. regenerated.
#[derive(Clone)]
pub struct Checkpoint {
    /// The job spec's seed — a cheap identity check before the plan hash.
    pub job_seed: u64,
    /// FNV-1a over the compiled plan's wire encoding.
    pub plan_hash: u64,
    /// Epoch the run is inside (`step / steps_per_epoch`).
    pub epoch: u64,
    /// Global minibatch steps completed.
    pub step: u64,
    /// Optimizer state: the SGD learning-rate shift the network trains
    /// with (validated against the rebuilt network on restore).
    pub grad_shift: u32,
    /// Training wall-clock already spent, for honest throughput reporting
    /// across restarts.
    pub seconds: f64,
    /// Live op counters at the cursor.
    pub ops: OpSnapshot,
    pub weights: Vec<LayerWeights>,
    /// Client codec RNG cursor (FHE: minibatch encryption draws).
    pub client_rng: Option<[u64; 4]>,
    /// Refresh-authority RNG cursor (FHE: re-encryption noise draws).
    pub auth_rng: Option<[u64; 4]>,
}

/// Hash binding a checkpoint to the compiled plan it was trained under.
pub fn plan_hash(plan: &Plan) -> u64 {
    fnv1a64(&plan.to_wire())
}

fn put_rng_opt(w: &mut WireWriter, s: &Option<[u64; 4]>) {
    match s {
        None => w.put_u8(0),
        Some(state) => {
            w.put_u8(1);
            for &x in state {
                w.put_u64(x);
            }
        }
    }
}

fn get_rng_opt(r: &mut WireReader<'_>) -> Result<Option<[u64; 4]>, WireError> {
    match r.u8()? {
        0 => Ok(None),
        1 => {
            let mut s = [0u64; 4];
            for x in &mut s {
                *x = r.u64()?;
            }
            Ok(Some(s))
        }
        other => Err(WireError::Malformed(format!("bad option discriminant {other}"))),
    }
}

impl WireCodec for Checkpoint {
    const TAG: [u8; 4] = *b"CKPT";
    const VERSION: u16 = 1;
    type Ctx = GlyphEngine;

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_u64(self.job_seed);
        w.put_u64(self.plan_hash);
        w.put_u64(self.epoch);
        w.put_u64(self.step);
        w.put_u32(self.grad_shift);
        w.put_f64(self.seconds);
        put_nested(w, &self.ops);
        w.put_len(self.weights.len());
        for lw in &self.weights {
            w.put_len(lw.unit);
            w.put_len(lw.rows.len());
            for row in &lw.rows {
                w.put_len(row.len());
                for ct in row {
                    put_nested(w, ct);
                }
            }
        }
        put_rng_opt(w, &self.client_rng);
        put_rng_opt(w, &self.auth_rng);
    }

    fn decode_body(r: &mut WireReader<'_>, engine: &GlyphEngine) -> Result<Self, WireError> {
        let job_seed = r.u64()?;
        let plan_hash = r.u64()?;
        let epoch = r.u64()?;
        let step = r.u64()?;
        let grad_shift = r.u32()?;
        let seconds = r.f64()?;
        let ops: OpSnapshot = get_nested(r, &())?;
        let layers = r.len(8)?;
        let mut weights = Vec::with_capacity(layers);
        for _ in 0..layers {
            let unit = r.u64()? as usize;
            let outs = r.len(8)?;
            let mut rows = Vec::with_capacity(outs);
            for _ in 0..outs {
                let ins = r.len(8)?;
                let mut row = Vec::with_capacity(ins);
                for _ in 0..ins {
                    row.push(get_nested::<Ct>(r, engine)?);
                }
                rows.push(row);
            }
            weights.push(LayerWeights { unit, rows });
        }
        let client_rng = get_rng_opt(r)?;
        let auth_rng = get_rng_opt(r)?;
        Ok(Checkpoint {
            job_seed,
            plan_hash,
            epoch,
            step,
            grad_shift,
            seconds,
            ops,
            weights,
            client_rng,
            auth_rng,
        })
    }
}

impl Checkpoint {
    /// Snapshot a live training run. `client_rng` is the job codec's RNG
    /// cursor on FHE (None on clear); the authority cursor is read off the
    /// engine.
    pub fn capture(
        net: &Network,
        engine: &GlyphEngine,
        job_seed: u64,
        epoch: u64,
        step: u64,
        seconds: f64,
        client_rng: Option<[u64; 4]>,
    ) -> Result<Checkpoint, WireError> {
        let mut weights = Vec::new();
        for (unit, fc) in net.fc_units() {
            if !fc.is_trainable() {
                continue;
            }
            let rows: Vec<Vec<Ct>> = fc
                .w
                .iter()
                .map(|row| {
                    row.iter()
                        .map(|wt| match wt {
                            Weight::Enc(ct) => Ok(ct.clone()),
                            Weight::Plain(_) => Err(WireError::Malformed(format!(
                                "trainable FC unit {unit} holds a plaintext weight"
                            ))),
                        })
                        .collect()
                })
                .collect::<Result<_, _>>()?;
            weights.push(LayerWeights { unit, rows });
        }
        let auth_rng = match &engine.backend {
            Backend::Fhe(f) => Some(f.auth.rng_state()),
            Backend::Clear(_) => None,
        };
        Ok(Checkpoint {
            job_seed,
            plan_hash: plan_hash(&net.plan),
            epoch,
            step,
            grad_shift: net.grad_shift,
            seconds,
            ops: engine.counter.snapshot(),
            weights,
            client_rng,
            auth_rng,
        })
    }

    /// Restore this checkpoint into a freshly rebuilt network: overwrite
    /// the trainable weights, reload the op counters, and reposition the
    /// authority RNG. The caller repositions the client codec RNG from
    /// [`Self::client_rng`] (the codec is not reachable through the
    /// engine) and resumes the step loop at [`Self::step`].
    pub fn restore(&self, net: &mut Network, engine: &GlyphEngine) -> Result<(), WireError> {
        if self.plan_hash != plan_hash(&net.plan) {
            return Err(WireError::Malformed(format!(
                "checkpoint was trained under a different compiled plan \
                 (stored {:#018x}, rebuilt {:#018x})",
                self.plan_hash,
                plan_hash(&net.plan)
            )));
        }
        if self.grad_shift != net.grad_shift {
            return Err(WireError::Malformed(format!(
                "checkpoint gradient shift {} does not match the rebuilt network's {}",
                self.grad_shift, net.grad_shift
            )));
        }
        self.restore_weights(net)?;
        engine.counter.store(&self.ops);
        if let (Some(state), Backend::Fhe(f)) = (self.auth_rng, &engine.backend) {
            f.auth.restore_rng_state(state);
            f.auth.restore_count(self.ops.refresh as usize);
        }
        Ok(())
    }

    /// Restore *only* the trained weight ciphertexts, with geometry checks
    /// but without the plan-hash / grad-shift binding or the counter and
    /// RNG repositioning of [`Self::restore`].
    ///
    /// This is the model-loading half of restore, for forward-only
    /// inference: an `InferenceSession` compiles a different (forward-only,
    /// possibly different-batch) plan than the one the model trained under,
    /// so the plan hash cannot match by construction — but the weights are
    /// still the exact trained ciphertexts, and mismatched layer geometry
    /// is still refused with a descriptive error.
    pub fn restore_weights(&self, net: &mut Network) -> Result<(), WireError> {
        for lw in &self.weights {
            let fc = net.fc_unit_mut(lw.unit).ok_or_else(|| {
                WireError::Malformed(format!("checkpoint names unit {} which is not an FC", lw.unit))
            })?;
            if lw.rows.len() != fc.out_dim || lw.rows.iter().any(|row| row.len() != fc.in_dim) {
                return Err(WireError::Malformed(format!(
                    "checkpoint unit {} weights are {}×{}, layer is {}×{}",
                    lw.unit,
                    lw.rows.len(),
                    lw.rows.first().map_or(0, Vec::len),
                    fc.out_dim,
                    fc.in_dim
                )));
            }
            for (j, row) in lw.rows.iter().enumerate() {
                for (i, ct) in row.iter().enumerate() {
                    fc.w[j][i] = Weight::Enc(ct.clone());
                }
            }
        }
        Ok(())
    }
}
