//! [`WireCodec`] implementations for the durable core types.

use super::{get_nested, put_nested, WireCodec, WireError, WireReader, WireWriter};
use crate::bgv::ciphertext::BgvCiphertext;
use crate::bgv::keys::{BgvContext, BgvSecretKey};
use crate::bgv::params::BgvParams;
use crate::bgv::refresh::NoiseRefresher;
use crate::coordinator::metrics::OpSnapshot;
use crate::coordinator::scheduler::{Plan, PlanStep, StepOps, StepPhase, System};
use crate::math::poly::RnsPoly;
use crate::math::rng::GlyphRng;
use crate::nn::backend::{ClearCt, Ct};
use crate::nn::engine::{Backend, ClientKeys, FheState, GlyphEngine};
use crate::nn::tensor::PackedLayout;
use crate::tfhe::lwe::LweCiphertext;
use crate::tfhe::params::TfheParams;
use std::sync::Arc;

impl WireCodec for BgvParams {
    const TAG: [u8; 4] = *b"BGVP";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_len(self.n);
        w.put_u64s(&self.primes);
        w.put_u64(self.t);
        w.put_f64(self.sigma);
        w.put_u64(self.prime_align);
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        let n = r.u64()? as usize;
        let primes = r.u64s()?;
        let t = r.u64()?;
        let sigma = r.f64()?;
        let prime_align = r.u64()?;
        if n == 0 || !n.is_power_of_two() {
            return Err(WireError::Malformed(format!("BGV ring degree {n} is not a power of two")));
        }
        if primes.is_empty() {
            return Err(WireError::Malformed("BGV parameter set has no primes".into()));
        }
        if t < 2 {
            return Err(WireError::Malformed(format!("BGV plaintext modulus t={t} is too small")));
        }
        Ok(BgvParams { n, primes, t, sigma, prime_align })
    }
}

impl WireCodec for TfheParams {
    const TAG: [u8; 4] = *b"TFHP";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_len(self.n);
        w.put_f64(self.alpha_lwe);
        w.put_len(self.big_n);
        w.put_f64(self.alpha_rlwe);
        w.put_len(self.l);
        w.put_u32(self.bg_bit);
        w.put_u32(self.ks_base_bit);
        w.put_len(self.ks_len);
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        let p = TfheParams {
            n: r.u64()? as usize,
            alpha_lwe: r.f64()?,
            big_n: r.u64()? as usize,
            alpha_rlwe: r.f64()?,
            l: r.u64()? as usize,
            bg_bit: r.u32()?,
            ks_base_bit: r.u32()?,
            ks_len: r.u64()? as usize,
        };
        if p.n == 0 || p.big_n == 0 || !p.big_n.is_power_of_two() {
            return Err(WireError::Malformed(format!(
                "TFHE dimensions n={} N={} are invalid",
                p.n, p.big_n
            )));
        }
        Ok(p)
    }
}

impl WireCodec for OpSnapshot {
    const TAG: [u8; 4] = *b"OPSN";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        let fields = self.fields();
        w.put_len(fields.len());
        for (_, v) in fields {
            w.put_u64(v);
        }
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        let names = OpSnapshot::default().fields();
        let n = r.len(8)?;
        if n != names.len() {
            return Err(WireError::Malformed(format!(
                "op snapshot has {n} counters, this build knows {}",
                names.len()
            )));
        }
        let mut pairs = Vec::with_capacity(n);
        for (name, _) in names {
            pairs.push((name, r.u64()?));
        }
        OpSnapshot::from_fields(pairs).map_err(WireError::Malformed)
    }
}

impl WireCodec for GlyphRng {
    const TAG: [u8; 4] = *b"XRNG";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        for x in self.state() {
            w.put_u64(x);
        }
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        let mut s = [0u64; 4];
        for x in &mut s {
            *x = r.u64()?;
        }
        Ok(GlyphRng::from_state(s))
    }
}

fn put_step_ops(w: &mut WireWriter, o: &StepOps) {
    w.put_u64(o.mult_cc);
    w.put_u64(o.mult_cp);
    w.put_u64(o.add_cc);
    w.put_u64(o.tlu);
    w.put_u64(o.relu_values);
    w.put_u64(o.softmax_values);
    w.put_u64(o.act_gates);
    w.put_u64(o.extract_pbs);
    w.put_u64(o.switch_b2t);
    w.put_u64(o.switch_t2b);
    w.put_u64(o.refresh);
    w.put_u64(o.extract_lanes);
    w.put_u64(o.repack_lanes);
}

fn get_step_ops(r: &mut WireReader<'_>) -> Result<StepOps, WireError> {
    Ok(StepOps {
        mult_cc: r.u64()?,
        mult_cp: r.u64()?,
        add_cc: r.u64()?,
        tlu: r.u64()?,
        relu_values: r.u64()?,
        softmax_values: r.u64()?,
        act_gates: r.u64()?,
        extract_pbs: r.u64()?,
        switch_b2t: r.u64()?,
        switch_t2b: r.u64()?,
        refresh: r.u64()?,
        extract_lanes: r.u64()?,
        repack_lanes: r.u64()?,
    })
}

impl WireCodec for Plan {
    const TAG: [u8; 4] = *b"PLAN";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_len(self.steps.len());
        for s in &self.steps {
            w.put_str(&s.name);
            match s.unit {
                None => w.put_u8(0),
                Some(u) => {
                    w.put_u8(1);
                    w.put_len(u);
                }
            }
            w.put_u8(match s.phase {
                StepPhase::Forward => 0,
                StepPhase::Error => 1,
                StepPhase::Gradient => 2,
            });
            w.put_u8(match s.system {
                System::Bgv => 0,
                System::Tfhe => 1,
            });
            w.put_u8(match s.switch {
                "-" => 0,
                "BGV-TFHE" => 1,
                "TFHE-BGV" => 2,
                other => unreachable!("unknown switch annotation {other:?}"),
            });
            put_step_ops(w, &s.ops);
            w.put_bool(s.fc_switch_overhead);
        }
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        let n = r.len(1)?;
        let mut steps = Vec::with_capacity(n);
        for _ in 0..n {
            let name = r.str()?;
            let unit = match r.u8()? {
                0 => None,
                1 => Some(r.u64()? as usize),
                other => {
                    return Err(WireError::Malformed(format!("bad option discriminant {other}")))
                }
            };
            let phase = match r.u8()? {
                0 => StepPhase::Forward,
                1 => StepPhase::Error,
                2 => StepPhase::Gradient,
                other => return Err(WireError::Malformed(format!("bad step phase {other}"))),
            };
            let system = match r.u8()? {
                0 => System::Bgv,
                1 => System::Tfhe,
                other => return Err(WireError::Malformed(format!("bad system {other}"))),
            };
            let switch = match r.u8()? {
                0 => "-",
                1 => "BGV-TFHE",
                2 => "TFHE-BGV",
                other => {
                    return Err(WireError::Malformed(format!("bad switch annotation {other}")))
                }
            };
            let ops = get_step_ops(r)?;
            let fc_switch_overhead = r.bool()?;
            steps.push(PlanStep { name, unit, phase, system, switch, ops, fc_switch_overhead });
        }
        Ok(Plan { steps })
    }
}

impl WireCodec for PackedLayout {
    const TAG: [u8; 4] = *b"PKLY";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_len(self.batch);
        w.put_len(self.stride);
        w.put_len(self.feats_per_ct);
        match &self.occupancy {
            None => w.put_u8(0),
            Some(mask) => {
                w.put_u8(1);
                w.put_len(mask.len());
                for &b in mask {
                    w.put_bool(b);
                }
            }
        }
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        let batch = r.u64()? as usize;
        let stride = r.u64()? as usize;
        let feats_per_ct = r.u64()? as usize;
        let occupancy = match r.u8()? {
            0 => None,
            1 => {
                let n = r.len(1)?;
                let mut mask = Vec::with_capacity(n);
                for _ in 0..n {
                    mask.push(r.bool()?);
                }
                Some(mask)
            }
            other => {
                return Err(WireError::Malformed(format!("bad occupancy discriminant {other}")))
            }
        };
        if batch == 0 || feats_per_ct == 0 {
            return Err(WireError::Malformed(format!(
                "packed layout needs batch ≥ 1 and F ≥ 1 (got batch {batch}, F {feats_per_ct})"
            )));
        }
        if stride < 2 * batch - 1 {
            return Err(WireError::Malformed(format!(
                "packed stride {stride} cannot isolate the ±{} cross-sample spread",
                batch - 1
            )));
        }
        if let Some(mask) = &occupancy {
            if mask.len() != batch {
                return Err(WireError::Malformed(format!(
                    "occupancy mask covers {} lanes, layout batch is {batch}",
                    mask.len()
                )));
            }
        }
        Ok(PackedLayout { batch, stride, feats_per_ct, occupancy })
    }
}

impl WireCodec for ClearCt {
    const TAG: [u8; 4] = *b"CLCT";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_len(self.n);
        w.put_u64(self.t);
        w.put_u64s(&self.coeffs);
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        let n = r.u64()? as usize;
        let t = r.u64()?;
        let coeffs = r.u64s()?;
        if coeffs.len() > n {
            return Err(WireError::Malformed(format!(
                "clear ciphertext has {} coefficients in a degree-{n} ring",
                coeffs.len()
            )));
        }
        if let Some(&bad) = coeffs.iter().find(|&&c| c >= t) {
            return Err(WireError::Malformed(format!(
                "clear ciphertext coefficient {bad} is outside Z_{t}"
            )));
        }
        Ok(ClearCt { n, t, coeffs })
    }
}

impl WireCodec for LweCiphertext {
    const TAG: [u8; 4] = *b"LWEC";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_u32s(&self.a);
        w.put_u32(self.b);
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        Ok(LweCiphertext { a: r.u32s()?, b: r.u32()? })
    }
}

fn put_rns_poly(w: &mut WireWriter, p: &RnsPoly) {
    w.put_bool(p.is_ntt);
    w.put_len(p.res.len());
    for limb in &p.res {
        w.put_u64s(limb);
    }
}

fn get_rns_poly(
    r: &mut WireReader<'_>,
    ctx: &BgvContext,
    level: usize,
) -> Result<RnsPoly, WireError> {
    let is_ntt = r.bool()?;
    let limbs = r.len(8)?;
    if limbs != level {
        return Err(WireError::Malformed(format!(
            "polynomial has {limbs} RNS limbs, ciphertext level is {level}"
        )));
    }
    let rctx = ctx.ctx_at(level);
    let mut res = Vec::with_capacity(limbs);
    for i in 0..limbs {
        let limb = r.u64s()?;
        if limb.len() != ctx.params.n {
            return Err(WireError::Malformed(format!(
                "RNS limb {i} has {} coefficients, ring degree is {}",
                limb.len(),
                ctx.params.n
            )));
        }
        let p = rctx.primes[i];
        if let Some(&bad) = limb.iter().find(|&&c| c >= p) {
            return Err(WireError::Malformed(format!(
                "residue {bad} in limb {i} exceeds its prime {p}"
            )));
        }
        res.push(limb);
    }
    Ok(RnsPoly { ctx: rctx.clone(), res, is_ntt, level })
}

impl WireCodec for BgvCiphertext {
    const TAG: [u8; 4] = *b"BGVC";
    const VERSION: u16 = 1;
    type Ctx = BgvContext;

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_len(self.level);
        put_rns_poly(w, &self.c0);
        put_rns_poly(w, &self.c1);
    }

    fn decode_body(r: &mut WireReader<'_>, ctx: &BgvContext) -> Result<Self, WireError> {
        let level = r.u64()? as usize;
        if level == 0 || level > ctx.top_level() {
            return Err(WireError::Malformed(format!(
                "ciphertext level {level} is outside 1..={}",
                ctx.top_level()
            )));
        }
        let c0 = get_rns_poly(r, ctx, level)?;
        let c1 = get_rns_poly(r, ctx, level)?;
        Ok(BgvCiphertext { c0, c1, level })
    }
}

impl WireCodec for Ct {
    const TAG: [u8; 4] = *b"CTCT";
    const VERSION: u16 = 1;
    type Ctx = GlyphEngine;

    fn encode_body(&self, w: &mut WireWriter) {
        match self {
            Ct::Clear(c) => {
                w.put_u8(0);
                put_nested(w, c);
            }
            Ct::Fhe(c) => {
                w.put_u8(1);
                put_nested(w, c);
            }
        }
    }

    fn decode_body(r: &mut WireReader<'_>, engine: &GlyphEngine) -> Result<Self, WireError> {
        match r.u8()? {
            0 => {
                let c: ClearCt = get_nested(r, &())?;
                if c.n != engine.params().n || c.t != engine.params().t {
                    return Err(WireError::Malformed(format!(
                        "clear ciphertext ring (n={}, t={}) does not match the engine \
                         (n={}, t={})",
                        c.n,
                        c.t,
                        engine.params().n,
                        engine.params().t
                    )));
                }
                Ok(Ct::Clear(c))
            }
            1 => match &engine.backend {
                Backend::Fhe(f) => Ok(Ct::Fhe(get_nested(r, f.ctx.as_ref())?)),
                Backend::Clear(_) => Err(WireError::Malformed(
                    "FHE ciphertext cannot be decoded on a clear-backend engine".into(),
                )),
            },
            other => Err(WireError::Malformed(format!("bad ciphertext variant {other}"))),
        }
    }
}

impl WireCodec for ClientKeys {
    const TAG: [u8; 4] = *b"CLNK";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        put_nested(w, &self.bgv_sk.ctx.params);
        w.put_i64s(&self.bgv_sk.s_coeffs);
        for x in self.rng.state() {
            w.put_u64(x);
        }
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        let params: BgvParams = get_nested(r, &())?;
        let s_coeffs = r.i64s()?;
        let mut state = [0u64; 4];
        for x in &mut state {
            *x = r.u64()?;
        }
        let ctx = BgvContext::new(params);
        let sk = BgvSecretKey::try_from_coeffs(&ctx, s_coeffs).map_err(WireError::Malformed)?;
        Ok(ClientKeys { bgv_sk: Arc::new(sk), rng: GlyphRng::from_state(state) })
    }
}

impl WireCodec for FheState {
    const TAG: [u8; 4] = *b"FHES";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        put_nested(w, &self.ctx.params);
        put_nested(w, &self.gate_ck.params);
        put_nested(w, &self.extract_ck.params);
        w.put_u64(self.seed);
        for x in self.auth.rng_state() {
            w.put_u64(x);
        }
        w.put_len(self.auth.refresh_count());
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        let bgv: BgvParams = get_nested(r, &())?;
        let gate: TfheParams = get_nested(r, &())?;
        let ext: TfheParams = get_nested(r, &())?;
        let seed = r.u64()?;
        let mut auth_rng = [0u64; 4];
        for x in &mut auth_rng {
            *x = r.u64()?;
        }
        let count = r.u64()? as usize;
        let state = FheState::generate(bgv, gate, ext, seed);
        state.auth.restore_rng_state(auth_rng);
        state.auth.restore_count(count);
        Ok(state)
    }
}
