//! `wire` — versioned, std-only binary serialization for every piece of
//! durable Glyph state (ROADMAP item 2: the serving/persistence layer).
//!
//! The crate is dependency-free by design (no serde), so this module
//! carries its own little-endian writer/reader pair and a [`WireCodec`]
//! trait. Every encoded payload is framed
//!
//! ```text
//! magic "GLYW" (4) | type tag (4) | version u16 | body len u64 | body | fnv1a-64 checksum u64
//! ```
//!
//! and decoding verifies each field in order, returning a descriptive
//! [`WireError`] — never panicking — on truncated, corrupted, foreign or
//! future-versioned bytes. The checksum covers everything before it
//! (header + body), so a single flipped bit anywhere is caught.
//!
//! Key material takes two deliberately different routes:
//!
//! * [`crate::nn::engine::ClientKeys`] is *structural*: parameters + secret
//!   coefficients + RNG cursor. The client must be able to move its key to
//!   another machine that knows nothing else.
//! * [`crate::nn::engine::FheState`] is *regenerative*: parameters + keygen
//!   seed + authority RNG cursor. Keygen is fully deterministic from the
//!   seed, so shipping gigabytes of FFT-domain cloud keys is pointless —
//!   decode replays `FheState::generate` and repositions the RNG cursors.
//!
//! [`Checkpoint`] (in [`checkpoint`]) is the durable unit the serve layer
//! writes every K steps: weights + op counters + step cursor + RNG cursors
//! + a hash of the compiled plan, enough to resume a training run
//! byte-identically in a fresh process.

mod checkpoint;
mod impls;

pub use checkpoint::{plan_hash, Checkpoint, LayerWeights};

/// Frame magic: every Glyph wire payload starts with these bytes.
pub const WIRE_MAGIC: [u8; 4] = *b"GLYW";

/// Bytes before the body: magic (4) + tag (4) + version (2) + body length
/// (8).
pub const HEADER_LEN: usize = 18;

/// Trailing checksum length.
pub const CHECKSUM_LEN: usize = 8;

/// What went wrong while decoding a wire payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The payload does not start with [`WIRE_MAGIC`] — not a Glyph wire
    /// frame at all.
    BadMagic { found: [u8; 4] },
    /// The frame is a Glyph payload of a different type.
    WrongTag { expected: [u8; 4], found: [u8; 4] },
    /// The frame's format version is not the one this build reads.
    UnsupportedVersion { tag: [u8; 4], found: u16, supported: u16 },
    /// Fewer bytes than the header/body length demand.
    Truncated { needed: usize, available: usize },
    /// More bytes than the header's body length accounts for.
    BadLength { declared: usize, actual: usize },
    /// Header + body do not hash to the stored checksum (bit rot or
    /// tampering).
    ChecksumMismatch { stored: u64, computed: u64 },
    /// The body parsed structurally but its contents are inconsistent.
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic { found } => {
                write!(f, "not a Glyph wire payload (magic {found:02x?}, want {WIRE_MAGIC:02x?})")
            }
            WireError::WrongTag { expected, found } => write!(
                f,
                "wire payload is a {:?} frame, expected {:?}",
                String::from_utf8_lossy(found),
                String::from_utf8_lossy(expected)
            ),
            WireError::UnsupportedVersion { tag, found, supported } => write!(
                f,
                "{:?} frame is format version {found}, this build reads version {supported}",
                String::from_utf8_lossy(tag)
            ),
            WireError::Truncated { needed, available } => {
                write!(f, "truncated wire payload: need {needed} bytes, have {available}")
            }
            WireError::BadLength { declared, actual } => {
                write!(f, "wire frame declares {declared} bytes but {actual} are present")
            }
            WireError::ChecksumMismatch { stored, computed } => write!(
                f,
                "wire checksum mismatch (stored {stored:#018x}, computed {computed:#018x}): \
                 payload is corrupted"
            ),
            WireError::Malformed(detail) => write!(f, "malformed wire payload: {detail}"),
        }
    }
}

impl std::error::Error for WireError {}

/// FNV-1a 64-bit — the frame checksum. Not cryptographic (the threat model
/// is bit rot and truncation, not forgery; encrypted state is protected by
/// the cryptosystem itself).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Little-endian append-only body writer.
#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> Self {
        WireWriter { buf: Vec::new() }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// A collection length (u64 on the wire regardless of platform).
    pub fn put_len(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_len(v.len());
        self.buf.extend_from_slice(v);
    }

    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    pub fn put_u64s(&mut self, v: &[u64]) {
        self.put_len(v.len());
        for &x in v {
            self.put_u64(x);
        }
    }

    pub fn put_u32s(&mut self, v: &[u32]) {
        self.put_len(v.len());
        for &x in v {
            self.put_u32(x);
        }
    }

    pub fn put_i64s(&mut self, v: &[i64]) {
        self.put_len(v.len());
        for &x in v {
            self.put_i64(x);
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian cursor reader over a body slice. Every accessor checks
/// bounds and returns [`WireError::Truncated`] instead of panicking.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { needed: self.pos + n, available: self.buf.len() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(WireError::Malformed(format!("bool byte must be 0/1, got {other}"))),
        }
    }

    pub fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64, WireError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A collection length, sanity-capped against the bytes actually
    /// present so a corrupted length can't trigger a huge allocation.
    pub fn len(&mut self, elem_size: usize) -> Result<usize, WireError> {
        let n = self.u64()?;
        let cap = (self.remaining() / elem_size.max(1)) as u64;
        if n > cap {
            return Err(WireError::Truncated {
                needed: self.pos + (n as usize).saturating_mul(elem_size),
                available: self.buf.len(),
            });
        }
        Ok(n as usize)
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], WireError> {
        let n = self.len(1)?;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String, WireError> {
        let raw = self.bytes()?;
        String::from_utf8(raw.to_vec())
            .map_err(|e| WireError::Malformed(format!("invalid utf-8 string: {e}")))
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>, WireError> {
        let n = self.len(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn u32s(&mut self) -> Result<Vec<u32>, WireError> {
        let n = self.len(4)?;
        (0..n).map(|_| self.u32()).collect()
    }

    pub fn i64s(&mut self) -> Result<Vec<i64>, WireError> {
        let n = self.len(8)?;
        (0..n).map(|_| self.i64()).collect()
    }

    /// Assert the body was consumed exactly.
    pub fn finish(self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::Malformed(format!(
                "{} unread bytes after the last field",
                self.remaining()
            )));
        }
        Ok(())
    }
}

/// A type with a stable binary wire format. `Ctx` is whatever shared state
/// decoding needs (`()` for self-contained types; a `BgvContext` for
/// ciphertexts whose RNS limbs hang off per-level contexts; a `GlyphEngine`
/// for checkpoints).
pub trait WireCodec: Sized {
    /// Frame type tag (four ASCII bytes, unique per type).
    const TAG: [u8; 4];
    /// Current format version; bump on any body layout change.
    const VERSION: u16;
    /// Decode-side context.
    type Ctx: ?Sized;

    fn encode_body(&self, w: &mut WireWriter);
    fn decode_body(r: &mut WireReader<'_>, ctx: &Self::Ctx) -> Result<Self, WireError>;

    /// Full framed encoding: header + body + checksum.
    fn to_wire(&self) -> Vec<u8> {
        let mut body = WireWriter::new();
        self.encode_body(&mut body);
        let body = body.into_bytes();
        let mut out = Vec::with_capacity(HEADER_LEN + body.len() + CHECKSUM_LEN);
        out.extend_from_slice(&WIRE_MAGIC);
        out.extend_from_slice(&Self::TAG);
        out.extend_from_slice(&Self::VERSION.to_le_bytes());
        out.extend_from_slice(&(body.len() as u64).to_le_bytes());
        out.extend_from_slice(&body);
        let sum = fnv1a64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Verify the frame and decode. Exactly-sized input is required — a
    /// length-prefixed transport or a whole file supplies that naturally.
    fn from_wire(bytes: &[u8], ctx: &Self::Ctx) -> Result<Self, WireError> {
        if bytes.len() < HEADER_LEN + CHECKSUM_LEN {
            return Err(WireError::Truncated {
                needed: HEADER_LEN + CHECKSUM_LEN,
                available: bytes.len(),
            });
        }
        let magic: [u8; 4] = bytes[0..4].try_into().unwrap();
        if magic != WIRE_MAGIC {
            return Err(WireError::BadMagic { found: magic });
        }
        let tag: [u8; 4] = bytes[4..8].try_into().unwrap();
        if tag != Self::TAG {
            return Err(WireError::WrongTag { expected: Self::TAG, found: tag });
        }
        let version = u16::from_le_bytes(bytes[8..10].try_into().unwrap());
        if version != Self::VERSION {
            return Err(WireError::UnsupportedVersion {
                tag,
                found: version,
                supported: Self::VERSION,
            });
        }
        let body_len = u64::from_le_bytes(bytes[10..18].try_into().unwrap()) as usize;
        let framed = HEADER_LEN + body_len + CHECKSUM_LEN;
        if bytes.len() < framed {
            return Err(WireError::Truncated { needed: framed, available: bytes.len() });
        }
        if bytes.len() > framed {
            return Err(WireError::BadLength { declared: framed, actual: bytes.len() });
        }
        let stored = u64::from_le_bytes(bytes[framed - CHECKSUM_LEN..].try_into().unwrap());
        let computed = fnv1a64(&bytes[..framed - CHECKSUM_LEN]);
        if stored != computed {
            return Err(WireError::ChecksumMismatch { stored, computed });
        }
        let mut r = WireReader::new(&bytes[HEADER_LEN..framed - CHECKSUM_LEN]);
        let value = Self::decode_body(&mut r, ctx)?;
        r.finish()?;
        Ok(value)
    }
}

/// Encode a nested value as a length-prefixed sub-frame (own header +
/// checksum, so every component is independently verifiable).
pub fn put_nested<T: WireCodec>(w: &mut WireWriter, v: &T) {
    w.put_bytes(&v.to_wire());
}

/// Decode a nested sub-frame written by [`put_nested`].
pub fn get_nested<T: WireCodec>(r: &mut WireReader<'_>, ctx: &T::Ctx) -> Result<T, WireError> {
    let blob = r.bytes()?;
    T::from_wire(blob, ctx)
}

/// Write `bytes` to `path` atomically: a unique temp file in the same
/// directory, then rename. A `kill -9` mid-write leaves either the old
/// checkpoint or the new one, never a torn file.
pub fn write_atomic(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    let dir = path.parent().unwrap_or_else(|| std::path::Path::new("."));
    std::fs::create_dir_all(dir)?;
    let tmp = dir.join(format!(
        ".{}.tmp-{}",
        path.file_name().and_then(|n| n.to_str()).unwrap_or("wire"),
        std::process::id()
    ));
    std::fs::write(&tmp, bytes)?;
    match std::fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Pair {
        a: u64,
        s: String,
    }

    impl WireCodec for Pair {
        const TAG: [u8; 4] = *b"TPAI";
        const VERSION: u16 = 1;
        type Ctx = ();

        fn encode_body(&self, w: &mut WireWriter) {
            w.put_u64(self.a);
            w.put_str(&self.s);
        }

        fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
            Ok(Pair { a: r.u64()?, s: r.str()? })
        }
    }

    #[test]
    fn frame_roundtrip_and_header_checks() {
        let p = Pair { a: 7, s: "hello".into() };
        let bytes = p.to_wire();
        let back = Pair::from_wire(&bytes, &()).unwrap();
        assert_eq!(back.a, 7);
        assert_eq!(back.s, "hello");

        // magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(matches!(Pair::from_wire(&bad, &()), Err(WireError::BadMagic { .. })));
        // truncation at every prefix must error, never panic
        for cut in 0..bytes.len() {
            assert!(Pair::from_wire(&bytes[..cut], &()).is_err(), "cut at {cut}");
        }
        // trailing junk
        let mut long = bytes.clone();
        long.push(0);
        assert!(matches!(Pair::from_wire(&long, &()), Err(WireError::BadLength { .. })));
        // corrupted body byte
        let mut corrupt = bytes.clone();
        corrupt[HEADER_LEN] ^= 1;
        assert!(matches!(Pair::from_wire(&corrupt, &()), Err(WireError::ChecksumMismatch { .. })));
        // future version (checksum refreshed so the version check fires)
        let mut vbump = bytes.clone();
        vbump[8] = 0xff;
        let sum = fnv1a64(&vbump[..vbump.len() - CHECKSUM_LEN]);
        let at = vbump.len() - CHECKSUM_LEN;
        vbump[at..].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(Pair::from_wire(&vbump, &()), Err(WireError::UnsupportedVersion { .. })));
    }

    #[test]
    fn reader_rejects_oversized_lengths() {
        // a u64 length far beyond the buffer must not allocate
        let mut w = WireWriter::new();
        w.put_u64(u64::MAX);
        let body = w.into_bytes();
        let mut r = WireReader::new(&body);
        assert!(matches!(r.u64s(), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn atomic_write_replaces_whole_file() {
        let dir = std::env::temp_dir().join(format!("glyph-wire-test-{}", std::process::id()));
        let path = dir.join("state.bin");
        write_atomic(&path, b"first").unwrap();
        write_atomic(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
