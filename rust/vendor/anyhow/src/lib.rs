//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! The build environment has no network access and no vendored registry, so
//! this crate provides exactly the surface the repo uses: [`Error`],
//! [`Result`], the [`Context`] extension trait and the `anyhow!` / `bail!` /
//! `ensure!` macros. Errors are plain message strings — good enough for the
//! CLI / example drivers, which only ever print them.

use std::fmt;

/// A string-backed error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Prefix the error with context (innermost cause last).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, so this
// blanket conversion cannot overlap the identity `From<Error> for Error`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/glyph")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_prefixes() {
        let r: Result<()> = io_fail().context("loading dataset");
        let msg = format!("{}", r.unwrap_err());
        assert!(msg.starts_with("loading dataset: "), "{msg}");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).is_err());
        assert!(f(200).is_err());
    }
}
