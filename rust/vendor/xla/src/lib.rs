//! Minimal vendored stub of the `xla` (PJRT) crate surface used by
//! `glyph::runtime`.
//!
//! The build environment has no network access, so the real PJRT bindings
//! cannot be pulled in. This stub keeps the runtime module compiling and the
//! CPU "client" constructible (so `Runtime::new` succeeds and smoke tests
//! pass); every operation that would actually need XLA — HLO parsing,
//! compilation, execution — returns a clear [`Error`] instead. The AOT
//! artifact path degrades gracefully: callers already treat a failed
//! `load()` as "artifacts unavailable" and fall back to the native Rust
//! kernels (see `benches/ablations.rs`).

use std::fmt;

/// Stub error: always "backend unavailable" with the failing operation.
#[derive(Debug, Clone)]
pub struct Error {
    what: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XLA/PJRT backend unavailable in this build: {}", self.what)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error { what: what.to_string() })
}

/// Element types a [`Literal`] can carry (only the ones the repo names).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    F64,
    U8,
    U32,
    U64,
    S32,
    S64,
}

/// Marker for element types accepted by [`Literal::vec1`] / [`Literal::to_vec`].
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}
impl NativeType for u64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side tensor stand-in: shape bookkeeping only.
#[derive(Clone, Debug, Default)]
pub struct Literal {
    elements: usize,
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { elements: data.len() }
    }

    /// Reshape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.elements {
            return unavailable("reshape with mismatched element count");
        }
        Ok(self.clone())
    }

    /// Split a tuple literal into its parts. Never reachable in the stub
    /// (execution fails first), kept for API parity.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }

    /// Element-type conversion. Never reachable in the stub.
    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        unavailable("Literal::convert")
    }

    /// Copy out as a host vector. Never reachable in the stub.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module. Construction always fails in the stub.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HLO text parsing (build the real PJRT bindings to enable artifacts)")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle. Never materialized in the stub.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable. Never materialized in the stub.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute over input literals; `[replica][output]` buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client. The CPU "client" constructs (one virtual device) so code
/// can probe for the runtime without failing at startup.
pub struct PjRtClient {
    devices: usize,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { devices: 1 })
    }

    pub fn device_count(&self) -> usize {
        self.devices
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_constructs() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.device_count(), 1);
    }

    #[test]
    fn literal_shape_bookkeeping() {
        let l = Literal::vec1(&[1.0f32; 6]);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn heavy_ops_report_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let msg = format!("{}", PjRtBuffer.to_literal_sync().unwrap_err());
        assert!(msg.contains("unavailable"), "{msg}");
    }
}
