//! Quickstart for the plan-driven `Network` API: declare a model with the
//! `NetworkBuilder`, inspect its compiled cryptosystem schedule, run an
//! encrypted forward pass (BGV FC MACs → switch → TFHE Algorithm-1 ReLU),
//! decrypt, and check against plaintext.
//!
//!     cargo run --release --example quickstart

use glyph::math::GlyphRng;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::nn::network::NetworkBuilder;
use glyph::nn::tensor::{EncTensor, PackOrder};

fn main() -> anyhow::Result<()> {
    let batch = 4;
    println!("• generating keys (test profile)…");
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 42);
    let mut rng = GlyphRng::new(1);

    // A 3→2 FC layer with encrypted weights, followed by a TFHE ReLU —
    // one fluent builder chain.
    let w = vec![vec![2i64, -1, 3], vec![-2, 4, 1]];
    println!("• building network: .fc_encrypted(3→2).relu(0, 0)");
    let net = NetworkBuilder::input_vec(3)
        .fc_encrypted(w.clone())
        .relu(0, 0)
        .build(&mut client, &mut rng, &engine)?;

    println!("• compiled schedule (the Switch column of the paper's tables):");
    for s in &net.plan.steps {
        println!("    {:<14} {:<6?} switch: {}", s.name, s.system, s.switch);
    }

    // Inputs: 3 features × batch 4 (8-bit signed).
    let x_cols = vec![vec![10i64, -10, 5, 0], vec![7, 7, -7, 1], vec![-3, 3, 3, 2]];
    println!("• encrypting inputs {x_cols:?}");
    let x_cts = x_cols.iter().map(|v| client.encrypt_batch(v, 0)).collect();
    let x = EncTensor::new(x_cts, vec![3], PackOrder::Forward, 0);

    println!("• forward pass (walks the plan: BGV MACs → switch → TFHE ReLU)…");
    let pass = net.forward(&x, &engine);
    let a = pass.output();

    println!("• decrypting:");
    for j in 0..2 {
        let got = client.decrypt_batch(&a.cts[j], batch, 0);
        let want: Vec<i64> = (0..batch)
            .map(|b| (0..3).map(|i| w[j][i] * x_cols[i][b]).sum::<i64>().max(0))
            .collect();
        println!("  neuron {j}: got {got:?}  want {want:?}");
        assert_eq!(got, want);
    }
    println!("• HOP counts: {}", engine.counter.snapshot());
    let t = net.plan.totals();
    println!("• plan predicted: {} MultCC, {} gates, {} B2T switches", t.mult_cc, t.act_gates, t.switch_b2t);
    println!("✓ quickstart OK");
    Ok(())
}
