//! Quickstart: encrypt a mini-batch, run one FC + TFHE-ReLU layer through
//! the cryptosystem switch, decrypt, and check against plaintext.
//!
//!     cargo run --release --example quickstart

use glyph::nn::activation::relu_layer;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::nn::linear::FcLayer;
use glyph::nn::tensor::{EncTensor, PackOrder};

fn main() -> anyhow::Result<()> {
    let batch = 4;
    println!("• generating keys (test profile)…");
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 42);

    // A 3→2 FC layer with encrypted weights.
    let w = vec![vec![2i64, -1, 3], vec![-2, 4, 1]];
    let layer = FcLayer::new_encrypted(&w, &mut client, 0);

    // Inputs: 3 features × batch 4 (8-bit signed).
    let x_cols = vec![vec![10i64, -10, 5, 0], vec![7, 7, -7, 1], vec![-3, 3, 3, 2]];
    println!("• encrypting inputs {x_cols:?}");
    let x_cts = x_cols.iter().map(|v| client.encrypt_batch(v, 0)).collect();
    let x = EncTensor::new(x_cts, vec![3], PackOrder::Forward, 0);

    println!("• FC forward on BGV (MultCC MACs)…");
    let u = layer.forward(&x, &engine);

    println!("• switching to TFHE and running Algorithm-1 ReLU…");
    let (a, _state) = relu_layer(&engine, &u, 0, PackOrder::Forward);

    println!("• decrypting:");
    for j in 0..2 {
        let got = client.decrypt_batch(&a.cts[j], batch, 0);
        let want: Vec<i64> = (0..batch)
            .map(|b| (0..3).map(|i| w[j][i] * x_cols[i][b]).sum::<i64>().max(0))
            .collect();
        println!("  neuron {j}: got {got:?}  want {want:?}");
        assert_eq!(got, want);
    }
    println!("• HOP counts: {}", engine.counter.snapshot());
    println!("✓ quickstart OK");
    Ok(())
}
