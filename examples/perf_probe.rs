// standalone micro-profile of the MultCC hot path
use glyph::nn::engine::{EngineProfile, GlyphEngine};
fn main() {
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Default, 60, 1);
    let w = client.encrypt_scalar(9);
    let x = client.encrypt_batch(&vec![17; 60], 0);
    // warmup
    for _ in 0..5 { let mut t = w.clone(); t.mul_assign(&x, &engine.rlk, &engine.ctx); }
    let t0 = std::time::Instant::now();
    for _ in 0..100 { let mut t = w.clone(); t.mul_assign(&x, &engine.rlk, &engine.ctx); }
    println!("MultCC (N=2048, L=3): {:.3} ms", t0.elapsed().as_secs_f64() * 10.0);
    let mut a = x.clone();
    let t0 = std::time::Instant::now();
    for _ in 0..100 { a.c0.to_coeff(); a.c0.to_ntt(); }
    println!("NTT fwd+inv pair (3 limbs): {:.3} ms", t0.elapsed().as_secs_f64() * 10.0);
}
