// standalone micro-profile of the two hot paths: the BGV MultCC (NTT MAC)
// and the TFHE gate bootstrap (PBS pipeline), sequential and pooled.
// Appends machine-readable numbers to bench_out/BENCH_perf_probe.json.
use glyph::bench_util::{report_json, BenchRecord};
use glyph::coordinator::GlyphPool;
use glyph::math::GlyphRng;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::tfhe::{encode_bit, LweCiphertext, LweKey, TfheCloudKey, TfheParams, TrlweKey};

fn main() {
    // ---- BGV MultCC -------------------------------------------------------
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Default, 60, 1);
    let fhe = engine.fhe();
    let w = client.encrypt_scalar(9);
    let x = client.encrypt_batch(&vec![17; 60], 0);
    // warmup
    for _ in 0..5 {
        let mut t = w.fhe().clone();
        t.mul_assign(x.fhe(), &fhe.rlk, &fhe.ctx);
    }
    let t0 = std::time::Instant::now();
    for _ in 0..100 {
        let mut t = w.fhe().clone();
        t.mul_assign(x.fhe(), &fhe.rlk, &fhe.ctx);
    }
    let t_multcc = t0.elapsed().as_secs_f64() / 100.0;
    println!("MultCC (N=2048, L=3): {:.3} ms", t_multcc * 1000.0);
    let mut a = x.fhe().clone();
    let t0 = std::time::Instant::now();
    for _ in 0..100 {
        a.c0.to_coeff();
        a.c0.to_ntt();
    }
    println!("NTT fwd+inv pair (3 limbs): {:.3} ms", t0.elapsed().as_secs_f64() * 10.0);

    // ---- TFHE gate bootstrap (PBS pipeline) -------------------------------
    let params = TfheParams::test_params();
    let mut rng = GlyphRng::new(7);
    let key = LweKey::generate_binary(params.n, &mut rng);
    let ring = TrlweKey::generate(params.big_n, &mut rng);
    let ck = TfheCloudKey::generate(&key, &ring, &params, &mut rng);
    let enc = |b: bool, rng: &mut GlyphRng| {
        LweCiphertext::encrypt(encode_bit(b), &key, params.alpha_lwe, rng)
    };
    let c1 = enc(true, &mut rng);
    let c2 = enc(false, &mut rng);
    let k = 64usize;
    let pairs: Vec<(&LweCiphertext, &LweCiphertext)> = (0..k).map(|_| (&c1, &c2)).collect();
    // warm the thread-local scratch, the pool workers and their scratches
    let _ = ck.and(&c1, &c2);
    let _ = ck.and_many(&pairs);
    let t0 = std::time::Instant::now();
    for (x1, x2) in &pairs {
        let _ = ck.and(x1, x2);
    }
    let t_seq = t0.elapsed().as_secs_f64() / k as f64;
    let t0 = std::time::Instant::now();
    let _ = ck.and_many(&pairs);
    let t_pool = t0.elapsed().as_secs_f64() / k as f64;
    let threads = GlyphPool::global().threads();
    println!(
        "gate bootstrap: {:.3} ms/op sequential ({:.1} ops/s) | {:.3} ms/op across {} threads ({:.1} ops/s)",
        t_seq * 1000.0,
        1.0 / t_seq,
        t_pool * 1000.0,
        threads,
        1.0 / t_pool
    );
    report_json(
        "perf_probe",
        &[
            BenchRecord::new("mult_cc", t_multcc, 1),
            BenchRecord::new("gate_bootstrap", t_seq, 1),
            BenchRecord::new("gate_bootstrap_pool", t_pool, threads),
        ],
    );
}
