//! End-to-end driver: encrypted Glyph MLP training on (synthetic-fallback)
//! MNIST at reduced scale — every layer of the stack composes: BGV MACs,
//! the BGV↔TFHE switch, TFHE ReLU/softmax gates, gradient requantization
//! through the switch, SGD updates on encrypted weights.
//!
//!     cargo run --release --example mnist_glyph -- [steps] [batch]
//!
//! The run is recorded in EXPERIMENTS.md (§End-to-end).

use glyph::data;
use glyph::math::GlyphRng;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::nn::linear::Weight;
use glyph::nn::tensor::{EncTensor, PackOrder};
use glyph::train::{GlyphMlp, MlpConfig};

// The MLP is built through the `NetworkBuilder` (via the `MlpConfig`
// compatibility constructor); its execution walks the compiled plan.

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(3);
    let batch: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    // 8×8 downsampled images → 64 features, 4 classes.
    let (in_dim, hidden, classes) = (64usize, 16usize, 4usize);

    println!("Glyph encrypted MLP training — reduced scale ({in_dim}-{hidden}-{classes}, batch {batch})");
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 20260710);
    let mut rng = GlyphRng::new(7);
    let mut config = MlpConfig::tiny(in_dim, hidden, classes);
    config.act_shifts = vec![8, 7];
    let mut mlp = GlyphMlp::new_random(config, &mut client, &mut rng, &engine)?;
    let ds = data::mnist(true, batch * steps, 3);
    println!("dataset: {} ({} samples)", ds.name, ds.len());

    let downsample = |img: &[i64]| -> Vec<i64> {
        // 28×28 → 8×8 by 3×3 average over a 24×24 center crop
        (0..64)
            .map(|f| {
                let (by, bx) = (2 + (f / 8) * 3, 2 + (f % 8) * 3);
                let mut s = 0i64;
                for dy in 0..3 {
                    for dx in 0..3 {
                        s += img[(by + dy) * 28 + bx + dx];
                    }
                }
                s / 9
            })
            .collect()
    };

    for step in 0..steps {
        // pack features × batch
        let feats: Vec<Vec<i64>> = (0..batch).map(|b| downsample(&ds.image_i8(step * batch + b))).collect();
        let x_cts = (0..in_dim)
            .map(|f| client.encrypt_batch(&(0..batch).map(|b| feats[b][f]).collect::<Vec<_>>(), 0))
            .collect();
        let x = EncTensor::new(x_cts, vec![in_dim], PackOrder::Forward, 0);
        let lab_cts = (0..classes)
            .map(|k| {
                let mut v: Vec<i64> = (0..batch)
                    .map(|b| if ds.labels[step * batch + b] % classes == k { 127 } else { 0 })
                    .collect();
                v.reverse();
                client.encrypt_batch(&v, 0)
            })
            .collect();
        let labels = EncTensor::new(lab_cts, vec![classes], PackOrder::Reversed, 0);

        let before = engine.counter.snapshot();
        let t0 = std::time::Instant::now();
        mlp.train_step(&x, &labels, &engine);
        let dt = t0.elapsed().as_secs_f64();
        let d = engine.counter.snapshot().since(&before);
        // decrypted weight-magnitude proxy: shows learning signal moving
        let w00 = match &mlp.fc_layers()[0].w[0][0] {
            Weight::Enc(ct) => client.decrypt_batch(ct, 1, 0)[0],
            Weight::Plain(p) => p.value(),
        };
        println!("step {step}: {dt:.1}s  {d}  w[0][0][0]={w00}");
    }
    println!("✓ end-to-end encrypted training completed ({} refreshes, trust-model note in README)", engine.counter.snapshot().refresh);
    Ok(())
}
