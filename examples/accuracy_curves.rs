//! Figures 7/8: accuracy-vs-epoch curves, trained in the plaintext domain
//! exactly as the paper evaluates them ("all networks are trained in the
//! plaintext domain"), through the AOT JAX/Pallas artifacts via PJRT —
//! python never runs here.
//!
//! Three variants per dataset: FHESGD-style MLP, Glyph CNN, Glyph CNN with
//! transfer learning (conv weights pre-trained on the source set via the
//! cnn_pretrain_step artifact, then frozen by cnn_transfer_step).
//!
//!     cargo run --release --example accuracy_curves -- [--dataset mnist|cancer] [--epochs N]

use anyhow::Result;
use glyph::data::{self, Dataset};
use glyph::runtime::{Artifact, Runtime};

const BATCH: usize = 60;

struct Params(Vec<(Vec<f32>, Vec<usize>)>);

impl Params {
    fn inputs<'a>(&'a self, extra: &[(&'a [f32], &'a [usize])]) -> Vec<(&'a [f32], &'a [usize])> {
        let mut v: Vec<(&[f32], &[usize])> =
            self.0.iter().map(|(d, s)| (d.as_slice(), s.as_slice())).collect();
        v.extend_from_slice(extra);
        v
    }
}

fn init_params(shapes: &[Vec<usize>], seed: u64) -> Params {
    let mut rng = glyph::math::GlyphRng::new(seed);
    Params(
        shapes
            .iter()
            .map(|s| {
                let n: usize = s.iter().product();
                let fan_in = s[..s.len() - 1.min(s.len())].iter().product::<usize>().max(1);
                let std = (2.0 / fan_in as f64).sqrt() as f32 * 0.7;
                ((0..n).map(|_| rng.gaussian(std as f64) as f32).collect(), s.clone())
            })
            .collect(),
    )
}

fn batch_xy(ds: &Dataset, idx: &[usize], flat: bool) -> (Vec<f32>, Vec<f32>) {
    let (c, h, w) = ds.shape;
    let mut x = Vec::with_capacity(idx.len() * c * h * w);
    let mut y = vec![0f32; idx.len() * ds.classes];
    for (bi, &i) in idx.iter().enumerate() {
        x.extend_from_slice(&ds.images[i]);
        y[bi * ds.classes + ds.labels[i]] = 1.0;
    }
    let _ = flat;
    (x, y)
}

/// Run one epoch of training; returns updated params and mean loss.
fn train_epoch(step: &Artifact, params: Params, ds: &Dataset, xshape: &[usize], lr: f32) -> Result<(Params, f32)> {
    let nb = ds.len() / BATCH;
    let mut p = params;
    let mut loss_sum = 0f32;
    for b in 0..nb {
        let idx: Vec<usize> = (b * BATCH..(b + 1) * BATCH).collect();
        let (x, y) = batch_xy(ds, &idx, true);
        let yshape = [BATCH, ds.classes];
        let lr_s: [f32; 1] = [lr];
        let lr_shape: [usize; 0] = [];
        let outs = step.run_f32(&p.inputs(&[(&x, xshape), (&y, &yshape), (&lr_s, &lr_shape)]))?;
        let n_params = p.0.len();
        loss_sum += outs[n_params][0];
        p = Params(outs.into_iter().take(n_params).zip(p.0).map(|(d, (_, s))| (d, s)).collect());
    }
    Ok((p, loss_sum / nb as f32))
}

fn accuracy(infer: &Artifact, params: &Params, ds: &Dataset, xshape: &[usize]) -> Result<f64> {
    let nb = ds.len() / BATCH;
    let mut correct = 0usize;
    for b in 0..nb {
        let idx: Vec<usize> = (b * BATCH..(b + 1) * BATCH).collect();
        let (x, _) = batch_xy(ds, &idx, true);
        let outs = infer.run_f32(&params.inputs(&[(&x, xshape)]))?;
        for (bi, &i) in idx.iter().enumerate() {
            if outs[0][bi] as usize == ds.labels[i] {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / (nb * BATCH) as f64)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args
        .iter()
        .position(|a| a == "--dataset")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "mnist".into());
    let epochs: usize = args
        .iter()
        .position(|a| a == "--epochs")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    let train_n = 20 * BATCH;
    let test_n = 5 * BATCH;

    let rt = Runtime::from_env()?;
    println!("Figure {}: accuracy vs epoch on {dataset} (synthetic fallback data, {} train / {} test)",
        if dataset == "mnist" { 7 } else { 8 }, train_n, test_n);

    // datasets
    let (train, test, src): (Dataset, Dataset, Dataset) = if dataset == "mnist" {
        (data::mnist(true, train_n, 1), data::mnist(false, test_n, 2), data::synthetic_svhn(train_n, 3))
    } else {
        (data::synthetic_cancer(train_n, 1), data::synthetic_cancer(test_n, 2), data::synthetic_cifar(train_n, 3))
    };
    let (c, h, w) = train.shape;
    let classes = train.classes;

    // ---- MLP (FHESGD-style architecture) — only defined for 784-in MNIST
    let mut mlp_acc: Vec<f64> = Vec::new();
    if dataset == "mnist" {
        let step = rt.load("mlp_train_step")?;
        let infer = rt.load("mlp_infer")?;
        let shapes = vec![vec![784usize, 128], vec![128, 32], vec![32, 10]];
        let mut p = init_params(&shapes, 11);
        let xshape = vec![BATCH, 784];
        for _e in 0..epochs {
            let (np, _loss) = train_epoch(&step, p, &train, &xshape, 0.5)?;
            p = np;
            mlp_acc.push(accuracy(&infer, &p, &test, &xshape)?);
        }
    }

    // ---- CNN from scratch
    let suffix = if dataset == "mnist" { "mnist" } else { "cancer" };
    let pre = rt.load(&format!("cnn_pretrain_step_{suffix}"))?;
    let transfer = rt.load(&format!("cnn_transfer_step_{suffix}"))?;
    let infer = rt.load(&format!("cnn_infer_{suffix}"))?;
    let (c1, c2, fc1_in, fc1) = if dataset == "mnist" { (6, 16, 400, 84) } else { (64, 96, 2400, 128) };
    let shapes = vec![
        vec![c1, c, 3, 3],
        vec![c2, c1, 3, 3],
        vec![fc1_in, fc1],
        vec![fc1, classes],
    ];
    let xshape = vec![BATCH, c, h, w];

    let mut cnn_acc = Vec::new();
    let mut p = init_params(&shapes, 21);
    for _e in 0..epochs {
        let (np, _loss) = train_epoch(&pre, p, &train, &xshape, 1.0)?;
        p = np;
        cnn_acc.push(accuracy(&infer, &p, &test, &xshape)?);
    }

    // ---- CNN + transfer learning: pre-train on source, freeze convs
    let mut tl = init_params(&shapes, 31);
    for e in 0..6 {
        let (np, l) = train_epoch(&pre, tl, &src, &xshape, 0.3)?;
        tl = np;
        eprintln!("[pretrain] epoch {e}: loss {l:.4}");
    }
    // fresh head on top of the frozen pre-trained features. The pre-trained
    // conv features live at q8 scale (≈ ±127), so the head starts tiny and
    // trains with a correspondingly small learning rate — the plaintext
    // analogue of the encrypted head's grad_shift.
    let head = init_params(&shapes[2..], 41);
    tl.0[2] = head.0[0].clone();
    tl.0[3] = head.0[1].clone();
    let mut tl_acc = Vec::new();
    for e in 0..epochs {
        let (np, l) = train_epoch(&transfer, tl, &train, &xshape, 0.5)?;
        tl = np;
        tl_acc.push(accuracy(&infer, &tl, &test, &xshape)?);
        eprintln!("[tl] epoch {e}: loss {l:.4} acc {:.3}", tl_acc[e]);
    }

    println!("\n| epoch | {} CNN | CNN+TL |", if dataset == "mnist" { "MLP |" } else { "" });
    for e in 0..epochs {
        if dataset == "mnist" {
            println!("| {} | {:.3} | {:.3} | {:.3} |", e + 1, mlp_acc[e], cnn_acc[e], tl_acc[e]);
        } else {
            println!("| {} | {:.3} | {:.3} |", e + 1, cnn_acc[e], tl_acc[e]);
        }
    }
    let last = epochs - 1;
    println!("\nshape check: CNN+TL ≥ CNN at final epoch: {} ({:.3} vs {:.3})",
        tl_acc[last] >= cnn_acc[last] - 0.02, tl_acc[last], cnn_acc[last]);
    Ok(())
}
