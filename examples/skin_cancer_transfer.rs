//! Transfer learning on the (synthetic-fallback) Skin-Cancer dataset:
//! frozen plaintext convolutions (MultCP) + encrypted FC head training —
//! the paper's §4.3 / Table 8 pipeline at reduced scale.
//!
//!     cargo run --release --example skin_cancer_transfer

use glyph::data;
use glyph::math::GlyphRng;
use glyph::nn::batchnorm::BnLayer;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::nn::tensor::{EncTensor, PackOrder};
use glyph::train::transfer::{CnnConfig, GlyphCnn};

fn main() -> anyhow::Result<()> {
    let batch = 2;
    println!("Glyph CNN + transfer learning — reduced scale");
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 99);
    let mut rng = GlyphRng::new(5);
    let config = CnnConfig::tiny();

    // "Pre-trained" feature kernels: in the full pipeline these come from
    // the cnn_pretrain_step artifact on the CIFAR-like source set (see
    // examples/accuracy_curves.rs); here deterministic edge-ish filters.
    let edge = |s: i64| vec![vec![vec![s, 0, -s], vec![2 * s, 0, -2 * s], vec![s, 0, -s]]];
    let c1w = vec![edge(1), edge(-1)];
    let c2w: Vec<_> = (0..3)
        .map(|k| (0..2).map(|c| vec![vec![k as i64 - 1, 1, 0], vec![0, 1, 0], vec![0, 1, c as i64 - 1]]).collect())
        .collect();
    let bn1 = BnLayer { gain: vec![1, 1], bias: vec![0, 0], gain_shift: 0 };
    let bn2 = BnLayer { gain: vec![1, 1, 1], bias: vec![0, 0, 0], gain_shift: 0 };
    let mut cnn = GlyphCnn::new(config, &c1w, bn1, &c2w, bn2, &mut client, &mut rng, &engine)?;

    let ds = data::synthetic_cancer(batch, 11);
    // take channel 0, center 14×14 crop
    let cts = (0..14 * 14)
        .map(|i| {
            let (y, x) = (7 + i / 14, 7 + i % 14);
            let vals: Vec<i64> = (0..batch).map(|b| ds.image_i8(b)[y * 28 + x]).collect();
            client.encrypt_batch(&vals, 0)
        })
        .collect();
    let x = EncTensor::new(cts, vec![1, 14, 14], PackOrder::Forward, 0);
    let lab_cts = (0..2)
        .map(|k| {
            let mut v: Vec<i64> =
                (0..batch).map(|b| if ds.labels[b] % 2 == k { 127 } else { 0 }).collect();
            v.reverse();
            client.encrypt_batch(&v, 0)
        })
        .collect();
    let labels = EncTensor::new(lab_cts, vec![2], PackOrder::Reversed, 0);

    let t0 = std::time::Instant::now();
    cnn.train_step(&x, &labels, &engine);
    let s = engine.counter.snapshot();
    println!("one transfer-learning step: {:.1}s", t0.elapsed().as_secs_f64());
    println!("  {s}");
    println!(
        "  frozen convs ran {} MultCP; encrypted head ran {} MultCC — the paper's Table-8 split",
        s.mult_cp, s.mult_cc
    );
    assert!(s.mult_cp > s.mult_cc, "transfer learning must be MultCP-dominated");
    println!("✓ skin_cancer_transfer OK");
    Ok(())
}
